//! `gs` subcommands as thin adapters over [`RunConfig`].
//!
//! Every subcommand is a row in [`COMMANDS`]: a base config document
//! plus a table of flags, where each flag is nothing but an override
//! path into the document (`--epochs 5` ≡ `--set task.epochs=5`).
//! Parsing is strict: an unknown flag is a hard error with the nearest
//! valid flag suggested, and a value-taking flag refuses to swallow a
//! following `--flag` token — `gs train-nc --epcohs 10` can never
//! silently train 3 epochs again.

use anyhow::{anyhow, bail, Context, Result};

use super::{apply_set, did_you_mean, set_path, RunConfig};
use crate::util::json::Json;

/// One CLI flag: an override path into the config document.
#[derive(Debug, Clone, Copy)]
pub struct Flag {
    pub name: &'static str,
    pub takes_value: bool,
    /// Dot path into the run-config document, or a `#special`:
    /// `#conf` (load file as base), `#set` (generic override),
    /// `#lm` (`none` drops the stage), `#metis` (boolean method),
    /// `#side` (side-channel read by `main` via [`flag_value`], no
    /// config effect).
    pub path: &'static str,
    pub help: &'static str,
}

/// One `gs` subcommand: base document + flag table.
#[derive(Debug, Clone, Copy)]
pub struct Cmd {
    pub name: &'static str,
    pub about: &'static str,
    /// Base config document the flags override (ignored when `#conf`
    /// loads a file instead).
    pub base: &'static str,
    pub flags: &'static [Flag],
}

const SET: Flag = Flag {
    name: "set",
    takes_value: true,
    path: "#set",
    help: "stage.key=value override (repeatable, applied in order)",
};
const DATASET: Flag =
    Flag { name: "dataset", takes_value: true, path: "data.dataset", help: "mag|amazon|scale-free" };
const SIZE: Flag =
    Flag { name: "size", takes_value: true, path: "data.size", help: "generator size" };
const NUM_PARTS: Flag =
    Flag { name: "num-parts", takes_value: true, path: "partition.parts", help: "partitions" };
const METIS: Flag = Flag {
    name: "metis",
    takes_value: false,
    path: "#metis",
    help: "METIS-like partitioning (default random)",
};
const SEED: Flag = Flag { name: "seed", takes_value: true, path: "seed", help: "run seed" };
const NUM_WORKERS: Flag = Flag {
    name: "num-workers",
    takes_value: true,
    path: "loader.workers",
    help: "loader threads, or 'auto'",
};
const PREFETCH: Flag = Flag {
    name: "prefetch",
    takes_value: true,
    path: "loader.prefetch",
    help: "batches built ahead per worker",
};
const TRACE: Flag = Flag {
    name: "trace",
    takes_value: true,
    path: "obs.trace",
    help: "write a JSONL span/event trace here (docs/OBSERVABILITY.md)",
};
const STATS: Flag = Flag {
    name: "stats",
    takes_value: false,
    path: "obs.stats",
    help: "print the metrics-registry table at end of run",
};
const ARCH_TASK: Flag =
    Flag { name: "arch", takes_value: true, path: "task.arch", help: "rgcn|gcn|sage|gat|rgat|hgt" };
const EPOCHS: Flag =
    Flag { name: "epochs", takes_value: true, path: "task.epochs", help: "training epochs" };
const LR: Flag = Flag { name: "lr", takes_value: true, path: "task.lr", help: "learning rate" };

/// The `gs` command table.  `smoke` is handled directly in `main`;
/// everything else builds a [`RunConfig`] and hands it to the
/// pipeline executor.
pub const COMMANDS: &[Cmd] = &[
    Cmd {
        name: "run",
        about: "execute the pipeline a run-config file declares",
        base: "{}",
        flags: &[
            Flag { name: "conf", takes_value: true, path: "#conf", help: "run-config JSON file" },
            Flag {
                name: "dump-conf",
                takes_value: true,
                path: "#dump",
                help: "write the fully-resolved config JSON to this path",
            },
            Flag {
                name: "report",
                takes_value: true,
                path: "obs.report",
                help: "write the pipeline outcome (stage timings, metrics) as JSON here",
            },
            TRACE,
            STATS,
            SET,
        ],
    },
    Cmd {
        name: "validate-conf",
        about: "dry-run: parse, validate and print the fully-resolved config",
        base: "{}",
        flags: &[
            Flag { name: "conf", takes_value: true, path: "#conf", help: "run-config JSON file" },
            SET,
        ],
    },
    Cmd {
        name: "gen-data",
        about: "data + partition stages only (prints graph stats)",
        base: "{}",
        flags: &[DATASET, SIZE, NUM_PARTS, METIS, SEED, SET],
    },
    Cmd {
        name: "gconstruct",
        about: "construct from tabular files + schema config",
        base: r#"{"data": {"source": "gconstruct"}}"#,
        flags: &[
            Flag { name: "conf", takes_value: true, path: "data.conf", help: "gconstruct schema JSON" },
            Flag { name: "dir", takes_value: true, path: "data.dir", help: "tabular data directory" },
            NUM_PARTS,
            METIS,
            SET,
        ],
    },
    Cmd {
        name: "train-nc",
        about: "node classification training",
        base: r#"{"task": {"kind": "nc"}}"#,
        flags: &[
            DATASET,
            SIZE,
            NUM_PARTS,
            METIS,
            SEED,
            ARCH_TASK,
            EPOCHS,
            LR,
            Flag {
                name: "lm",
                takes_value: true,
                path: "#lm",
                help: "none|pretrained|finetuned LM stage",
            },
            Flag {
                name: "save-model-path",
                takes_value: true,
                path: "task.save_model",
                help: "save trained model (GSTF)",
            },
            NUM_WORKERS,
            PREFETCH,
            TRACE,
            STATS,
            SET,
        ],
    },
    Cmd {
        name: "train-lp",
        about: "link prediction training",
        base: r#"{"task": {"kind": "lp"}}"#,
        flags: &[
            DATASET,
            SIZE,
            NUM_PARTS,
            METIS,
            SEED,
            EPOCHS,
            LR,
            Flag { name: "loss", takes_value: true, path: "task.loss", help: "contrastive|ce" },
            Flag {
                name: "neg",
                takes_value: true,
                path: "task.neg",
                help: "in-batch|joint-K|local-joint-K|uniform-K",
            },
            Flag {
                name: "max-edges-per-epoch",
                takes_value: true,
                path: "task.max_edges_per_epoch",
                help: "training-edge cap per epoch",
            },
            NUM_WORKERS,
            PREFETCH,
            TRACE,
            STATS,
            SET,
        ],
    },
    Cmd {
        name: "distill",
        about: "GNN teacher -> graph-free student LM distillation",
        base: r#"{"task": {"kind": "distill"}}"#,
        flags: &[
            DATASET,
            SIZE,
            NUM_PARTS,
            METIS,
            SEED,
            ARCH_TASK,
            EPOCHS,
            LR,
            Flag {
                name: "teacher-epochs",
                takes_value: true,
                path: "task.teacher_epochs",
                help: "GNN teacher training epochs",
            },
            NUM_WORKERS,
            PREFETCH,
            TRACE,
            STATS,
            SET,
        ],
    },
    Cmd {
        name: "train-multi",
        about: "multi-task training: shared encoder + weighted nc/lp/distill heads",
        base: r#"{"tasks": [{"kind": "nc"}, {"kind": "distill"}]}"#,
        flags: &[
            DATASET,
            SIZE,
            NUM_PARTS,
            METIS,
            SEED,
            Flag {
                name: "arch",
                takes_value: true,
                path: "encoder.arch",
                help: "shared encoder architecture",
            },
            Flag {
                name: "epochs",
                takes_value: true,
                path: "encoder.epochs",
                help: "shared training epochs",
            },
            Flag {
                name: "lr",
                takes_value: true,
                path: "encoder.lr",
                help: "shared learning rate (per-task: --set tasks.N.lr=V)",
            },
            NUM_WORKERS,
            PREFETCH,
            TRACE,
            STATS,
            SET,
        ],
    },
    Cmd {
        name: "infer",
        about: "offline full-graph inference shards",
        base: r#"{"infer": {}}"#,
        flags: &[
            DATASET,
            SIZE,
            NUM_PARTS,
            METIS,
            SEED,
            Flag { name: "arch", takes_value: true, path: "infer.arch", help: "engine architecture" },
            Flag { name: "out-dim", takes_value: true, path: "infer.out_dim", help: "prediction width" },
            Flag { name: "out", takes_value: true, path: "infer.out", help: "shard output directory" },
            Flag { name: "shard-size", takes_value: true, path: "infer.shard_size", help: "rows per shard" },
            Flag { name: "ntype", takes_value: true, path: "infer.ntype", help: "node type (default: target)" },
            NUM_WORKERS,
            PREFETCH,
            TRACE,
            STATS,
            SET,
        ],
    },
    Cmd {
        name: "serve-bench",
        about: "closed-loop Zipf traffic through the micro-batcher + cache",
        base: r#"{"serve": {}}"#,
        flags: &[
            DATASET,
            SIZE,
            NUM_PARTS,
            METIS,
            SEED,
            Flag { name: "arch", takes_value: true, path: "serve.arch", help: "engine architecture" },
            Flag { name: "out-dim", takes_value: true, path: "serve.out_dim", help: "prediction width" },
            Flag { name: "requests", takes_value: true, path: "serve.requests", help: "trace length" },
            Flag { name: "alpha", takes_value: true, path: "serve.alpha", help: "Zipf exponent" },
            Flag { name: "clients", takes_value: true, path: "serve.clients", help: "closed-loop clients" },
            Flag { name: "cache", takes_value: true, path: "serve.cache", help: "embedding-cache capacity" },
            Flag {
                name: "pool-workers",
                takes_value: true,
                path: "serve.pool_workers",
                help: "engine-pool threads, or 'auto'",
            },
            Flag {
                name: "shards",
                takes_value: true,
                path: "serve.shards",
                help: "cache/table stripes (replies are shard-count-invariant)",
            },
            Flag {
                name: "sessions",
                takes_value: true,
                path: "serve.sessions",
                help: "parallel engine sessions, or 'auto' (clamped to pool workers)",
            },
            Flag {
                name: "admission",
                takes_value: true,
                path: "serve.admission",
                help: "cache admission: always|tinylfu",
            },
            Flag {
                name: "refresh",
                takes_value: true,
                path: "serve.refresh",
                help: "hot rows re-read after the mid-bench generation bump (0 = off)",
            },
            Flag { name: "max-batch", takes_value: true, path: "serve.max_batch", help: "micro-batch size cap" },
            Flag { name: "deadline-us", takes_value: true, path: "serve.deadline_us", help: "micro-batch deadline" },
            Flag {
                name: "faults",
                takes_value: true,
                path: "serve.faults",
                help: "fault plan for the uncached arm, e.g. 'panics=2,transient=3,slow=1'",
            },
            Flag {
                name: "deadline-ms",
                takes_value: true,
                path: "serve.deadline_ms",
                help: "per-request deadline in ms (0 = none)",
            },
            Flag {
                name: "max-retries",
                takes_value: true,
                path: "serve.max_retries",
                help: "bounded retries for retryable batch failures",
            },
            Flag {
                name: "queue-depth",
                takes_value: true,
                path: "serve.queue_depth",
                help: "shed new misses past this many pending requests (0 = never)",
            },
            Flag {
                name: "max-worker-restarts",
                takes_value: true,
                path: "serve.max_worker_restarts",
                help: "worker restarts before degraded mode",
            },
            TRACE,
            STATS,
            SET,
        ],
    },
    Cmd {
        name: "serve",
        about: "HTTP/1.1 front end: serve /predict over a socket until drained",
        base: r#"{"serve": {"http": {}}}"#,
        flags: &[
            DATASET,
            SIZE,
            NUM_PARTS,
            METIS,
            SEED,
            Flag {
                name: "listen",
                takes_value: true,
                path: "serve.http.listen",
                help: "bind address (port 0 = ephemeral)",
            },
            Flag {
                name: "http-workers",
                takes_value: true,
                path: "serve.http.workers",
                help: "connection-handler threads",
            },
            Flag {
                name: "max-body",
                takes_value: true,
                path: "serve.http.max_body",
                help: "request-body cap in bytes (413 beyond)",
            },
            Flag {
                name: "read-timeout-ms",
                takes_value: true,
                path: "serve.http.read_timeout_ms",
                help: "per-connection socket read timeout",
            },
            Flag {
                name: "write-timeout-ms",
                takes_value: true,
                path: "serve.http.write_timeout_ms",
                help: "per-connection socket write timeout",
            },
            Flag { name: "arch", takes_value: true, path: "serve.arch", help: "engine architecture" },
            Flag { name: "out-dim", takes_value: true, path: "serve.out_dim", help: "prediction width" },
            Flag { name: "cache", takes_value: true, path: "serve.cache", help: "embedding-cache capacity" },
            Flag {
                name: "pool-workers",
                takes_value: true,
                path: "serve.pool_workers",
                help: "engine-pool threads, or 'auto'",
            },
            Flag {
                name: "shards",
                takes_value: true,
                path: "serve.shards",
                help: "cache/table stripes (replies are shard-count-invariant)",
            },
            Flag {
                name: "sessions",
                takes_value: true,
                path: "serve.sessions",
                help: "parallel engine sessions, or 'auto' (clamped to pool workers)",
            },
            Flag {
                name: "admission",
                takes_value: true,
                path: "serve.admission",
                help: "cache admission: always|tinylfu",
            },
            Flag { name: "max-batch", takes_value: true, path: "serve.max_batch", help: "micro-batch size cap" },
            Flag { name: "deadline-us", takes_value: true, path: "serve.deadline_us", help: "micro-batch deadline" },
            Flag {
                name: "deadline-ms",
                takes_value: true,
                path: "serve.deadline_ms",
                help: "per-request deadline in ms (0 = none)",
            },
            Flag {
                name: "max-retries",
                takes_value: true,
                path: "serve.max_retries",
                help: "bounded retries for retryable batch failures",
            },
            Flag {
                name: "queue-depth",
                takes_value: true,
                path: "serve.queue_depth",
                help: "shed new misses past this many pending requests (0 = never)",
            },
            Flag {
                name: "max-worker-restarts",
                takes_value: true,
                path: "serve.max_worker_restarts",
                help: "worker restarts before degraded mode",
            },
            TRACE,
            STATS,
            SET,
        ],
    },
    Cmd {
        name: "load-bench",
        about: "closed-loop HTTP load harness against a running 'gs serve'",
        base: r#"{"serve": {"http": {}}}"#,
        flags: &[
            Flag {
                name: "addr",
                takes_value: true,
                path: "#side",
                help: "server address, e.g. 127.0.0.1:8080",
            },
            Flag {
                name: "connections",
                takes_value: true,
                path: "serve.clients",
                help: "persistent closed-loop connections",
            },
            Flag { name: "requests", takes_value: true, path: "serve.requests", help: "trace length" },
            Flag { name: "alpha", takes_value: true, path: "serve.alpha", help: "Zipf exponent" },
            SEED,
            Flag {
                name: "bench-out",
                takes_value: true,
                path: "#side",
                help: "merge http_* results into this BENCH_serve.json",
            },
            Flag {
                name: "shutdown",
                takes_value: false,
                path: "#side",
                help: "POST /shutdown (drain the server) after the run",
            },
            SET,
        ],
    },
];

/// Look up a subcommand, suggesting the nearest name on a miss.
pub fn find_command(name: &str) -> Result<&'static Cmd> {
    if let Some(c) = COMMANDS.iter().find(|c| c.name == name) {
        return Ok(c);
    }
    let mut names: Vec<&str> = COMMANDS.iter().map(|c| c.name).collect();
    names.push("smoke");
    names.push("stats");
    names.push("trace-check");
    names.push("help");
    Err(anyhow!(
        "unknown command '{name}'{}; run 'gs help' for usage",
        did_you_mean(name, &names)
    ))
}

/// Parse `args` against the command's flag table.  Unknown flags and
/// flags that would swallow a following `--flag` token are hard
/// errors.
pub fn parse_flags<'c>(cmd: &'c Cmd, args: &[String]) -> Result<Vec<(&'c Flag, String)>> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(name) = a.strip_prefix("--") else {
            bail!("unexpected argument '{a}' for 'gs {}' (flags look like --key value)", cmd.name);
        };
        let flag = cmd.flags.iter().find(|f| f.name == name).ok_or_else(|| {
            let valid: Vec<&str> = cmd.flags.iter().map(|f| f.name).collect();
            anyhow!(
                "unknown flag '--{name}' for 'gs {}'{}; valid flags: {}",
                cmd.name,
                did_you_mean(name, &valid),
                valid.iter().map(|v| format!("--{v}")).collect::<Vec<_>>().join(", ")
            )
        })?;
        i += 1;
        if flag.takes_value {
            match args.get(i) {
                Some(v) if !v.starts_with("--") => {
                    out.push((flag, v.clone()));
                    i += 1;
                }
                Some(v) => bail!(
                    "flag '--{name}' expects a value but the next token is the flag '{v}'"
                ),
                None => bail!("flag '--{name}' expects a value"),
            }
        } else {
            out.push((flag, "true".to_string()));
        }
    }
    Ok(out)
}

/// Build the config *document* for a command invocation: base (or
/// `--conf` file) + every flag override in CLI order.
pub fn build_doc(cmd: &Cmd, args: &[String]) -> Result<Json> {
    let flags = parse_flags(cmd, args)?;
    let needs_conf = cmd.flags.iter().any(|f| f.path == "#conf");
    if flags.iter().filter(|(f, _)| f.path == "#conf").count() > 1 {
        bail!("'gs {}': --conf given more than once", cmd.name);
    }
    let mut doc = match flags.iter().find(|(f, _)| f.path == "#conf") {
        Some((_, path)) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("read run config {path}"))?;
            Json::parse(&text).with_context(|| format!("parse run config {path}"))?
        }
        None if needs_conf => bail!("'gs {}' requires --conf FILE", cmd.name),
        None => Json::parse(cmd.base).expect("builtin base config parses"),
    };
    for (f, v) in &flags {
        match f.path {
            "#conf" | "#dump" | "#side" => {}
            "#set" => apply_set(&mut doc, v)?,
            "#metis" => set_path(&mut doc, "partition.method", "metis")?,
            "#lm" => {
                if v != "none" {
                    set_path(&mut doc, "lm.mode", v)?;
                }
            }
            path => set_path(&mut doc, path, v)?,
        }
    }
    Ok(doc)
}

/// Build and validate the typed config for a command invocation.
pub fn build_config(cmd: &Cmd, args: &[String]) -> Result<RunConfig> {
    RunConfig::from_json(&build_doc(cmd, args)?)
}

/// The (last) value of `--name` in `args`, if the flag was given —
/// how `main` reads side-channel flags like `run --dump-conf` that
/// are actions rather than config overrides.
pub fn flag_value(cmd: &Cmd, args: &[String], name: &str) -> Result<Option<String>> {
    Ok(parse_flags(cmd, args)?
        .into_iter()
        .rev()
        .find(|(f, _)| f.name == name)
        .map(|(_, v)| v))
}

/// The `gs help` text, generated from the command table so it can
/// never drift from the real flag set.
pub fn help_text() -> String {
    let mut s = String::new();
    s.push_str("gs — GraphStorm-rs: declarative graph ML pipelines (docs/CONFIG.md)\n\n");
    s.push_str("  gs run --conf examples/pipeline_nc.json   one command: data -> partition -> train -> infer\n");
    s.push_str("  gs <command> --set stage.key=value        any config key is overridable from the CLI\n\n");
    for cmd in COMMANDS {
        s.push_str(&format!("  gs {:<14} {}\n", cmd.name, cmd.about));
        for f in cmd.flags {
            if f.name == "set" && cmd.name != "run" {
                continue; // shown once under `run`
            }
            let val = if f.takes_value { " V" } else { "" };
            s.push_str(&format!("      --{:<22} {}\n", format!("{}{val}", f.name), f.help));
        }
    }
    s.push_str("  gs smoke          runtime sanity check (artifacts + PJRT)\n");
    s.push_str("  gs stats PATH     render a metrics snapshot JSON (--report output) as a table\n");
    s.push_str("  gs trace-check P  validate a --trace JSONL file against the trace schema\n");
    s.push_str("  gs lint [PATH]    static-analysis gate: determinism/panic-clean/lock-order/\n");
    s.push_str("                    salt-unique/name-registry rules over the source tree\n");
    s.push_str("                    (--dump-names prints the span/metric name table; docs/LINTS.md)\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataSource, Dataset, TaskKind, Workers};

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn typo_flag_is_error_with_suggestion() {
        let cmd = find_command("train-nc").unwrap();
        let e = build_config(cmd, &argv(&["--epcohs", "10"])).unwrap_err().to_string();
        assert!(e.contains("--epcohs") && e.contains("did you mean 'epochs'"), "{e}");
    }

    #[test]
    fn flag_cannot_swallow_next_flag() {
        let cmd = find_command("train-nc").unwrap();
        let e = build_config(cmd, &argv(&["--epochs", "--seed", "3"])).unwrap_err().to_string();
        assert!(e.contains("expects a value"), "{e}");
        let e = build_config(cmd, &argv(&["--epochs"])).unwrap_err().to_string();
        assert!(e.contains("expects a value"), "{e}");
    }

    #[test]
    fn adapter_builds_single_stage_config() {
        let cmd = find_command("train-nc").unwrap();
        let cfg = build_config(
            cmd,
            &argv(&["--dataset", "amazon", "--epochs", "10", "--num-parts", "2", "--metis",
                    "--num-workers", "auto", "--lm", "none"]),
        )
        .unwrap();
        let t = cfg.task.as_ref().unwrap();
        assert_eq!(t.kind, TaskKind::Nc);
        assert_eq!(t.epochs, 10);
        assert!(cfg.lm.is_none());
        assert_eq!(cfg.partition.parts, 2);
        assert_eq!(cfg.partition.method, crate::config::PartMethod::Metis);
        assert_eq!(cfg.loader.workers, Workers::Auto);
        match &cfg.data.source {
            DataSource::Gen { dataset, size } => {
                assert_eq!(*dataset, Dataset::Amazon);
                assert_eq!(*size, Dataset::Amazon.default_size());
            }
            other => panic!("wrong source {other:?}"),
        }
        // --lm pretrained creates the stage.
        let cfg = build_config(cmd, &argv(&["--lm", "finetuned"])).unwrap();
        assert_eq!(cfg.lm.as_ref().unwrap().mode, crate::config::LmMode::Finetuned);
    }

    #[test]
    fn train_multi_adapter_builds_tasks_array() {
        let cmd = find_command("train-multi").unwrap();
        let cfg = build_config(
            cmd,
            &argv(&[
                "--epochs", "2", "--arch", "rgcn",
                "--set", "tasks.0.weight=3",
                "--set", "tasks.1.lr=0.001",
            ]),
        )
        .unwrap();
        let m = cfg.multi.as_ref().unwrap();
        assert_eq!(m.encoder.epochs, 2);
        assert_eq!(m.tasks.len(), 2);
        assert!((m.tasks[0].weight - 3.0).abs() < 1e-12);
        assert!(m.tasks[1].lr.is_some());
        assert!(cfg.task.is_none());
        assert_eq!(cfg.train_options().epochs, 2);
    }

    #[test]
    fn set_flag_wins_over_earlier_flags() {
        let cmd = find_command("train-nc").unwrap();
        let cfg =
            build_config(cmd, &argv(&["--epochs", "4", "--set", "task.epochs=9"])).unwrap();
        assert_eq!(cfg.task.as_ref().unwrap().epochs, 9);
    }

    #[test]
    fn unknown_command_suggests() {
        let e = find_command("trian-nc").unwrap_err().to_string();
        assert!(e.contains("did you mean 'train-nc'"), "{e}");
        let e = find_command("stat").unwrap_err().to_string();
        assert!(e.contains("did you mean 'stats'"), "{e}");
    }

    #[test]
    fn obs_flags_set_obs_config() {
        let cmd = find_command("serve-bench").unwrap();
        let cfg =
            build_config(cmd, &argv(&["--trace", "t.jsonl", "--stats", "--requests", "50"]))
                .unwrap();
        assert_eq!(cfg.obs.trace.as_deref(), Some("t.jsonl"));
        assert!(cfg.obs.stats);
        assert_eq!(cfg.serve.as_ref().unwrap().requests, 50);
        // Without the flags, obs stays at its all-off default.
        let cfg = build_config(cmd, &argv(&[])).unwrap();
        assert_eq!(cfg.obs, crate::config::ObsCfg::default());
    }

    #[test]
    fn sharding_flags_set_serve_config() {
        let cmd = find_command("serve-bench").unwrap();
        let cfg = build_config(
            cmd,
            &argv(&["--pool-workers", "4", "--shards", "4", "--sessions", "2"]),
        )
        .unwrap();
        let s = cfg.serve.as_ref().unwrap();
        assert_eq!(s.shards, 4);
        assert_eq!(s.sessions, Workers::Fixed(2));
        let cfg = build_config(cmd, &argv(&["--sessions", "auto"])).unwrap();
        assert_eq!(cfg.serve.as_ref().unwrap().sessions, Workers::Auto);
        // An unknown-good combination dies at build time, not serve time.
        let e = build_config(cmd, &argv(&["--pool-workers", "2", "--sessions", "4"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("exceeds serve.pool_workers"), "{e}");
        let e = build_config(cmd, &argv(&["--shards", "0"])).unwrap_err().to_string();
        assert!(e.contains("serve.shards must be >= 1"), "{e}");
    }

    #[test]
    fn dump_conf_flag_value_extracted() {
        let cmd = find_command("run").unwrap();
        let args = argv(&["--conf", "x.json", "--dump-conf", "out.json"]);
        assert_eq!(flag_value(cmd, &args, "dump-conf").unwrap().as_deref(), Some("out.json"));
        assert_eq!(flag_value(cmd, &argv(&[]), "dump-conf").unwrap(), None);
        // Unknown flags still die even through the side channel.
        assert!(flag_value(cmd, &argv(&["--dmp-conf", "x"]), "dump-conf").is_err());
    }

    #[test]
    fn run_requires_conf() {
        let cmd = find_command("run").unwrap();
        let e = build_config(cmd, &argv(&[])).unwrap_err().to_string();
        assert!(e.contains("requires --conf"), "{e}");
    }

    #[test]
    fn every_flag_path_resolves() {
        // Drive each command with a benign value for every flag so a
        // typo'd `path:` in the table can never ship.
        for cmd in COMMANDS {
            if cmd.flags.iter().any(|f| f.path == "#conf") {
                continue; // needs a real file; covered elsewhere
            }
            let mut args: Vec<String> = Vec::new();
            for f in cmd.flags {
                let val = match f.name {
                    "dataset" => "mag",
                    "set" => "seed=9",
                    "lm" => "pretrained",
                    "loss" => "ce",
                    "neg" => "joint-16",
                    "arch" => "rgcn",
                    "admission" => "tinylfu",
                    "faults" => "panics=1,transient=2,slow=1",
                    "pool-workers" => "auto",
                    "alpha" => "1.2",
                    "listen" => "127.0.0.1:0",
                    "addr" => "127.0.0.1:1",
                    "bench-out" => "tmp_bench.json",
                    "lr" => "0.004",
                    "num-workers" => "2",
                    "out" => "tmp_out",
                    "trace" => "tmp_trace.jsonl",
                    "report" => "tmp_report.json",
                    "save-model-path" => "tmp_model.gstf",
                    "conf" => "schema.json",
                    "dir" => ".",
                    _ if f.takes_value => "2",
                    _ => "",
                };
                args.push(format!("--{}", f.name));
                if f.takes_value {
                    args.push(val.to_string());
                }
            }
            let cfg = build_config(cmd, &args)
                .unwrap_or_else(|e| panic!("gs {}: {e}", cmd.name));
            cfg.validate().unwrap();
        }
    }
}
