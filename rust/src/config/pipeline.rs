//! The pipeline executor: run a [`RunConfig`]'s declared stages in
//! order, threading one dataset/runtime through them — the paper's
//! "single command" property (`gs run --conf pipeline.json`).
//!
//! Stage semantics are identical to invoking each stage's subcommand
//! separately with the same seeds: dataset construction, partitioning
//! and every training/inference loop are deterministic functions of
//! the config, so a `gs run` pipeline reports bit-identical metrics to
//! the equivalent multi-command sequence (covered by
//! `tests/config.rs`).

use anyhow::{bail, Context, Result};

use super::{
    DataSource, Dataset, LmMode, PartMethod, PartitionCfg, RunConfig, TaskKind,
};
use crate::obs::metrics;
use crate::util::json::{obj, Json};
use crate::datagen::{self, amazon, mag, scale_free};
use crate::dataloader::GsDataset;
use crate::graph::{GraphStats, HeteroGraph};
use crate::partition::{metis_like_partition, random_partition, PartitionBook};
use crate::runtime::Runtime;
use crate::serve::{
    run_serve_bench, ClosedLoopStats, InferenceEngine, OfflineInference, OfflineReport,
    ServeBenchParams,
};
use crate::trainer::lp::{lp_train_artifact, LpReport, LP_EMB_ARTIFACT};
use crate::trainer::multi::MultiReport;
use crate::trainer::nc::NcReport;
use crate::trainer::{
    DistillTrainer, LmTrainer, LpTrainer, MultiTaskTrainer, NodeTrainer, TrainOptions,
};
use crate::util::StageTimer;

/// What a pipeline run produced, stage by stage.
#[derive(Debug, Clone, Default)]
pub struct PipelineOutcome {
    pub stats: Option<GraphStats>,
    pub nc: Option<NcReport>,
    pub lp: Option<LpReport>,
    pub distill_mse: Option<f32>,
    /// Per-task reports of a multi-task (`tasks: [...]`) run.
    pub multi: Option<MultiReport>,
    pub infer: Option<OfflineReport>,
    pub serve_uncached: Option<ClosedLoopStats>,
    pub serve_warmed: Option<ClosedLoopStats>,
    /// Post-generation-bump replay (present iff `serve.refresh > 0`).
    pub serve_refreshed: Option<ClosedLoopStats>,
    /// Wall-clock seconds per executed stage, in execution order
    /// (`data+partition` is one entry: construction binds them).
    pub stage_secs: Vec<(String, f64)>,
}

impl PipelineOutcome {
    /// The `--report PATH` JSON: stage timings, per-stage reports and
    /// the end-of-run metrics-registry snapshot in one machine-readable
    /// document (`gs stats PATH` renders the `metrics` sub-object).
    pub fn to_json(&self) -> Json {
        fn f32s(v: &[f32]) -> Json {
            Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
        }
        fn closed_loop(s: &ClosedLoopStats) -> Json {
            obj(vec![
                ("requests", Json::from(s.requests)),
                ("wall_s", Json::Num(s.wall_s)),
                ("rps", Json::Num(s.rps)),
                ("p50_us", Json::Num(s.p50_us)),
                ("p99_us", Json::Num(s.p99_us)),
                ("hit_rate", Json::Num(s.hit_rate)),
                ("hits", Json::from(s.hits as usize)),
                ("misses", Json::from(s.misses as usize)),
                ("coalesced", Json::from(s.coalesced as usize)),
                ("restarts", Json::from(s.restarts as usize)),
                ("retries", Json::from(s.retries as usize)),
                ("shed", Json::from(s.shed as usize)),
                ("deadline_misses", Json::from(s.deadline_misses as usize)),
            ])
        }
        let mut pairs = vec![(
            "stage_secs",
            Json::Arr(
                self.stage_secs
                    .iter()
                    .map(|(n, s)| {
                        obj(vec![("stage", Json::from(n.as_str())), ("secs", Json::Num(*s))])
                    })
                    .collect(),
            ),
        )];
        if let Some(s) = &self.stats {
            pairs.push((
                "graph",
                obj(vec![
                    ("num_nodes", Json::from(s.num_nodes)),
                    ("num_edges", Json::from(s.num_edges)),
                    ("num_ntypes", Json::from(s.num_ntypes)),
                    ("num_etypes", Json::from(s.num_etypes)),
                ]),
            ));
        }
        if let Some(r) = &self.nc {
            pairs.push((
                "nc",
                obj(vec![
                    ("epoch_losses", f32s(&r.epoch_losses)),
                    ("val_acc", Json::Num(r.val_acc)),
                    ("test_acc", Json::Num(r.test_acc)),
                    ("steps", Json::from(r.steps)),
                ]),
            ));
        }
        if let Some(r) = &self.lp {
            pairs.push((
                "lp",
                obj(vec![
                    ("epoch_losses", f32s(&r.epoch_losses)),
                    ("val_mrr", Json::Num(r.val_mrr)),
                    ("test_mrr", Json::Num(r.test_mrr)),
                    ("best_epoch", Json::from(r.best_epoch)),
                    ("steps", Json::from(r.steps)),
                ]),
            ));
        }
        if let Some(mse) = self.distill_mse {
            pairs.push(("distill_mse", Json::Num(mse as f64)));
        }
        if let Some(m) = &self.multi {
            let mut mp = vec![
                ("names", Json::Arr(m.names.iter().map(|n| Json::from(n.as_str())).collect())),
                ("epoch_losses", Json::Arr(m.epoch_losses.iter().map(|l| f32s(l)).collect())),
                ("steps", Json::Arr(m.steps.iter().map(|&s| Json::from(s)).collect())),
            ];
            if let Some(nc) = &m.nc {
                mp.push(("val_acc", Json::Num(nc.val_acc)));
                mp.push(("test_acc", Json::Num(nc.test_acc)));
            }
            if let Some(lp) = &m.lp {
                mp.push(("val_mrr", Json::Num(lp.val_mrr)));
                mp.push(("test_mrr", Json::Num(lp.test_mrr)));
            }
            if let Some(mse) = m.distill_mse {
                mp.push(("distill_mse", Json::Num(mse as f64)));
            }
            pairs.push(("multi", obj(mp)));
        }
        if let Some(r) = &self.infer {
            pairs.push((
                "infer",
                obj(vec![
                    ("ntype", Json::from(r.ntype as usize)),
                    ("rows", Json::from(r.rows)),
                    ("dim", Json::from(r.dim)),
                    ("shards", Json::from(r.shards.len())),
                    ("secs", Json::Num(r.secs)),
                ]),
            ));
        }
        for (key, arm) in [
            ("serve_uncached", &self.serve_uncached),
            ("serve_warmed", &self.serve_warmed),
            ("serve_refreshed", &self.serve_refreshed),
        ] {
            if let Some(s) = arm {
                pairs.push((key, closed_loop(s)));
            }
        }
        pairs.push(("metrics", metrics::snapshot()));
        obj(pairs)
    }
}

/// Executes the stages a [`RunConfig`] declares.
pub struct Pipeline {
    /// The fully-resolved config (defaults materialized, `"auto"`
    /// workers resolved — once, with a log line).
    pub cfg: RunConfig,
}

impl Pipeline {
    pub fn new(cfg: RunConfig) -> Result<Pipeline> {
        cfg.validate()?;
        Ok(Pipeline { cfg: cfg.resolved() })
    }

    /// The partition book for a graph under this config's `partition`
    /// stage (seed differs between gen and gconstruct sources to stay
    /// bit-compatible with both legacy subcommand paths).
    fn book(g: &HeteroGraph, pc: &PartitionCfg, seed: u64) -> PartitionBook {
        if pc.parts <= 1 {
            PartitionBook::single(&g.num_nodes)
        } else if pc.method == PartMethod::Metis {
            metis_like_partition(g, pc.parts, seed)
        } else {
            random_partition(g, pc.parts, seed)
        }
    }

    /// `data` + `partition` stages: construct the bound dataset.
    pub fn build_dataset(&self) -> Result<GsDataset> {
        let cfg = &self.cfg;
        let mut ds = match &cfg.data.source {
            DataSource::Gen { dataset, size } => {
                let raw = match dataset {
                    Dataset::Mag => mag::generate(&mag::MagConfig {
                        n_papers: *size,
                        ..Default::default()
                    }),
                    Dataset::Amazon => {
                        let world = amazon::generate_world(&amazon::ArConfig {
                            n_items: *size,
                            ..Default::default()
                        });
                        amazon::build_variant(&world, amazon::ArVariant::HeteroV2)
                    }
                    Dataset::ScaleFree => scale_free::generate(&scale_free::ScaleFreeConfig {
                        n_edges: *size,
                        ..Default::default()
                    }),
                };
                let book = Self::book(&raw.graph, &cfg.partition, cfg.seed);
                datagen::build_dataset(raw, book, cfg.data.lemb_dim, cfg.seed)
            }
            DataSource::GConstruct { conf, dir } => {
                let gcfg =
                    crate::gconstruct::GConstructConfig::load(std::path::Path::new(conf))?;
                let raw = crate::gconstruct::construct(&gcfg, std::path::Path::new(dir))?;
                let book = Self::book(&raw.graph, &cfg.partition, gcfg.seed);
                crate::gconstruct::bind_dataset(&gcfg, raw, book, cfg.data.lemb_dim)?
            }
        };
        // Text nodes get hashed bag-of-tokens features; an `lm` stage
        // later overwrites them with learned embeddings.
        ds.ensure_text_features(cfg.data.text_dim);
        Ok(ds)
    }

    /// Run every declared stage in order.
    pub fn run(&self) -> Result<PipelineOutcome> {
        let cfg = &self.cfg;
        // Arm tracing (iff a trace output is configured) and start this
        // run's metrics epoch; the epilogue below drains both.
        crate::obs::init(&cfg.obs);
        metrics::reset();
        let mut out = PipelineOutcome::default();
        let mut timer = StageTimer::default();

        // ---- data + partition ------------------------------------------
        let mut ds = timer.time("data+partition", || {
            let _sp = crate::span!("pipeline.data+partition");
            self.build_dataset()
        })?;
        let s = ds.graph.stats();
        match &cfg.data.source {
            DataSource::Gen { dataset, .. } => println!(
                "dataset={} nodes={} edges={} ntypes={} etypes={}",
                dataset.name(),
                s.num_nodes,
                s.num_edges,
                s.num_ntypes,
                s.num_etypes
            ),
            DataSource::GConstruct { .. } => println!(
                "constructed: nodes={} edges={} ntypes={} etypes={} parts={}",
                s.num_nodes, s.num_edges, s.num_ntypes, s.num_etypes, ds.engine.book.n_parts
            ),
        }
        out.stats = Some(s);

        let opts = cfg.train_options();
        let rt = if cfg.lm.is_some() || cfg.task.is_some() || cfg.multi.is_some() {
            Some(Runtime::from_default_dir()?)
        } else {
            None
        };

        // ---- lm ---------------------------------------------------------
        if let Some(lmc) = &cfg.lm {
            let rt = rt.as_ref().expect("lm stage validated to need the runtime");
            timer.time("lm", || -> Result<()> {
                let _sp = crate::span!("pipeline.lm");
                let lm = LmTrainer::default();
                let (_, st) = lm.pretrain_mlm(
                    rt,
                    &ds,
                    ds.target_ntype,
                    &TrainOptions { epochs: lmc.pretrain_epochs, ..opts.clone() },
                )?;
                let params = if lmc.mode == LmMode::Finetuned {
                    let (_, st2) = lm.finetune_nc(
                        rt,
                        &ds,
                        &st.params_host()?,
                        &TrainOptions { epochs: lmc.finetune_epochs, ..opts.clone() },
                    )?;
                    st2.params_host()?
                } else {
                    st.params_host()?
                };
                let secs = lm.embed_all(rt, &mut ds, &params, &opts)?;
                println!("lm embed stage: {secs:.1}s");
                Ok(())
            })?;
        }

        // ---- task -------------------------------------------------------
        if let Some(task) = &cfg.task {
            let rt = rt.as_ref().expect("task stage needs the runtime");
            timer.time(&format!("task({})", task.kind.name()), || -> Result<()> {
            let _sp = crate::span!("pipeline.task", kind = task.kind.name());
            match task.kind {
                TaskKind::Nc => {
                    let arch = &task.arch;
                    let trainer = NodeTrainer::new(
                        &format!("{arch}_nc_train"),
                        &format!("{arch}_nc_logits"),
                    );
                    let (report, st) = trainer.fit(rt, &mut ds, &opts)?;
                    println!(
                        "val_acc={:.4} test_acc={:.4} losses={:?}",
                        report.val_acc, report.test_acc, report.epoch_losses
                    );
                    if let Some(path) = &task.save_model {
                        st.save(std::path::Path::new(path))?;
                        println!("saved model to {path}");
                    }
                    out.nc = Some(report);
                }
                TaskKind::Lp => {
                    let artifact = lp_train_artifact(task.neg);
                    let mut trainer =
                        LpTrainer::new(&artifact, LP_EMB_ARTIFACT, task.loss, task.neg);
                    trainer.max_train_edges = Some(task.max_edges_per_epoch);
                    let (report, _) = trainer.fit(rt, &mut ds, &opts)?;
                    println!(
                        "val_mrr={:.4} test_mrr={:.4} best_epoch={} epoch_time={:.1}s",
                        report.val_mrr,
                        report.test_mrr,
                        report.best_epoch,
                        report.epoch_times.iter().sum::<f64>()
                            / report.epoch_times.len().max(1) as f64
                    );
                    out.lp = Some(report);
                }
                TaskKind::Distill => {
                    let arch = &task.arch;
                    let teacher = NodeTrainer::new(
                        &format!("{arch}_nc_train"),
                        &format!("{arch}_nc_logits"),
                    );
                    let topts = TrainOptions { epochs: task.teacher_epochs, ..opts.clone() };
                    let (trep, tst) = teacher.fit(rt, &mut ds, &topts)?;
                    println!(
                        "teacher val_acc={:.4} test_acc={:.4}",
                        trep.val_acc, trep.test_acc
                    );
                    let dt = DistillTrainer::default();
                    let (mse, _st) = dt.distill(rt, &ds, &tst.params_host()?, &opts)?;
                    println!("distill mse={mse:.5}");
                    out.distill_mse = Some(mse);
                }
            }
            Ok(())
            })?;
        }

        // ---- tasks (multi-task) ----------------------------------------
        if let Some(mc) = &cfg.multi {
            let rt = rt.as_ref().expect("tasks stage needs the runtime");
            let kinds: Vec<&str> = mc.tasks.iter().map(|t| t.kind.name()).collect();
            timer.time(&format!("tasks({})", kinds.join("+")), || -> Result<()> {
                let _sp = crate::span!("pipeline.tasks", n = mc.tasks.len());
                let trainer = MultiTaskTrainer::new(&mc.encoder.arch, mc.task_specs());
                let report = trainer.fit(rt, &mut ds, &opts)?;
                for (t, name) in report.names.iter().enumerate() {
                    println!(
                        "[multi {name}] losses={:?} steps={}",
                        report.epoch_losses[t], report.steps[t]
                    );
                }
                if let Some(nc) = &report.nc {
                    println!("[multi nc] val_acc={:.4} test_acc={:.4}", nc.val_acc, nc.test_acc);
                }
                if let Some(lp) = &report.lp {
                    println!("[multi lp] val_mrr={:.4} test_mrr={:.4}", lp.val_mrr, lp.test_mrr);
                }
                if let Some(mse) = report.distill_mse {
                    println!("[multi distill] mse={mse:.5}");
                }
                out.multi = Some(report);
                Ok(())
            })?;
        }

        // ---- infer ------------------------------------------------------
        if let Some(ic) = &cfg.infer {
            // `resolved()` (Pipeline::new) materialized the arch; don't
            // restate the default here.
            let arch = ic.arch.as_deref().expect("resolved() fills infer.arch");
            timer.time("infer", || -> Result<()> {
            let _sp = crate::span!("pipeline.infer");
            let (engine, backend) = InferenceEngine::auto(&ds, arch, ic.out_dim, cfg.seed)?;
            let off = OfflineInference {
                shard_size: ic.shard_size,
                prefetch: cfg.loader.prefetch_cfg(),
            };
            let ntype = ic.ntype.unwrap_or(ds.target_ntype) as u32;
            let rep = off.run(&engine, ntype, std::path::Path::new(&ic.out))?;
            println!(
                "offline inference [{backend}]: {} rows x {} dims in {:.2}s ({:.0} rows/s) -> {} shards under {}",
                rep.rows,
                rep.dim,
                rep.secs,
                rep.rows as f64 / rep.secs.max(1e-9),
                rep.shards.len(),
                ic.out,
            );
            out.infer = Some(rep);
            Ok(())
            })?;
        }

        // ---- serve ------------------------------------------------------
        if let Some(sc) = &cfg.serve {
            let arch = sc.arch.as_deref().expect("resolved() fills serve.arch");
            timer.time("serve", || -> Result<()> {
            let _sp = crate::span!("pipeline.serve", requests = sc.requests);
            let (engine, backend) = InferenceEngine::auto(&ds, arch, sc.out_dim, cfg.seed)?;
            let nt = ds.target_ntype as u32;
            let n_nodes = ds.graph.num_nodes[nt as usize];
            let pool = sc.pool();
            println!(
                "serve-bench [{backend}]: {} requests, zipf(a={}) over {n_nodes} nodes, {} clients, pool={} workers x {} sessions, {} cache shards, max_batch={}, deadline={}us, admission={}",
                sc.requests,
                sc.alpha,
                sc.clients,
                pool.workers,
                pool.sessions,
                sc.shards,
                pool.batcher.max_batch,
                pool.batcher.deadline.as_micros(),
                sc.admission.name(),
            );
            let rep = run_serve_bench(
                &engine,
                &ServeBenchParams {
                    seed: cfg.seed,
                    requests: sc.requests,
                    alpha: sc.alpha,
                    clients: sc.clients,
                    cache: sc.cache,
                    shards: sc.shards,
                    admission: sc.admission,
                    pool,
                    refresh: sc.refresh,
                    faults: sc.fault_spec()?,
                },
            )?;
            if rep.planned_faults > 0 {
                println!(
                    "  faults injected (uncached arm): {} planned; {} restarts, {} retries, {} shed, {} deadline misses",
                    rep.planned_faults,
                    rep.uncached.restarts,
                    rep.uncached.retries,
                    rep.uncached.shed,
                    rep.uncached.deadline_misses,
                );
            }
            println!(
                "  uncached:  p50 {:>7.0}us  p99 {:>7.0}us  {:>8.0} req/s  hit {:>5.1}%",
                rep.uncached.p50_us,
                rep.uncached.p99_us,
                rep.uncached.rps,
                100.0 * rep.uncached.hit_rate
            );
            println!(
                "  warmed:    p50 {:>7.0}us  p99 {:>7.0}us  {:>8.0} req/s  hit {:>5.1}%  (cache cap {}, {} distinct)",
                rep.warmed.p50_us,
                rep.warmed.p99_us,
                rep.warmed.rps,
                100.0 * rep.warmed.hit_rate,
                sc.cache,
                rep.distinct
            );
            if let Some(r) = &rep.refreshed {
                println!(
                    "  refreshed: p50 {:>7.0}us  p99 {:>7.0}us  {:>8.0} req/s  hit {:>5.1}%  ({} hot rows re-read after bump)",
                    r.p50_us,
                    r.p99_us,
                    r.rps,
                    100.0 * r.hit_rate,
                    rep.refreshed_rows
                );
            }
            println!(
                "  bit-identical across arms + repeats: {}; warmed speedup {:.2}x",
                rep.identical,
                rep.warmed.rps / rep.uncached.rps.max(1e-9)
            );
            let identical = rep.identical;
            out.serve_uncached = Some(rep.uncached);
            out.serve_warmed = Some(rep.warmed);
            out.serve_refreshed = rep.refreshed;
            if !identical {
                bail!("cached serving diverged from uncached recompute");
            }
            Ok(())
            })?;
        }

        out.stage_secs = timer.stages.clone();
        if !out.stage_secs.is_empty() {
            let parts: Vec<String> =
                out.stage_secs.iter().map(|(n, s)| format!("{n} {s:.2}s")).collect();
            println!("stage times: {}  (total {:.2}s)", parts.join(" | "), timer.total());
        }

        // ---- observability epilogue ------------------------------------
        // Publish pipeline-level metrics, then emit whatever `obs.*`
        // outputs the run configured (all off by default).
        for (name, secs) in &out.stage_secs {
            metrics::gauge_set(&format!("pipeline.stage_secs.{name}"), *secs);
        }
        let traffic = ds.engine.counters.snapshot();
        metrics::counter_set("dist.local_elems", traffic.local_elems);
        metrics::counter_set("dist.remote_elems", traffic.remote_elems);
        metrics::counter_set("dist.remote_bytes", traffic.remote_bytes);
        #[cfg(feature = "count-alloc")]
        {
            let (n, b) = crate::obs::alloc_counts();
            metrics::counter_set("alloc.count", n);
            metrics::counter_set("alloc.bytes", b);
        }
        if cfg.obs.stats {
            print!("{}", metrics::render_table(&metrics::snapshot()));
        }
        if let Some(path) = &cfg.obs.report {
            let mut body = out.to_json().to_string_pretty();
            body.push('\n');
            std::fs::write(path, body)
                .with_context(|| format!("write pipeline report {path}"))?;
            println!("pipeline report -> {path}");
        }
        let n = crate::obs::finish(&cfg.obs)?;
        if n > 0 {
            if let Some(p) = &cfg.obs.trace {
                println!("trace: {n} events -> {p}");
            }
            if let Some(p) = &cfg.obs.chrome_trace {
                println!("chrome trace: {n} events -> {p}");
            }
        }
        Ok(out)
    }
}
