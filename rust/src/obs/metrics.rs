//! Process-wide metrics registry: named counters, gauges and log₂
//! histograms, snapshotable as JSON and renderable as a table.
//!
//! Naming convention (docs/OBSERVABILITY.md): dotted lower-case paths,
//! `<subsystem>.<arm?>.<metric>` — e.g. `serve.warmed.hits`,
//! `trainer.nc.epoch_loss`, `dist.remote_bytes`, `pipeline.stage.task_nc_secs`.
//! Every subsystem publishes into this one registry so `gs stats` and
//! the end-of-run summary see one flat namespace.
//!
//! Producers keep their own lock-free counters (`ServeMetrics`,
//! `dist::TrafficCounters`, trainer reports) and publish here at stage
//! boundaries — the registry is a reporting surface, not a hot-path
//! data structure, so publishing costs nothing while a stage runs.
//!
//! [`closed_loop_snapshot`] is deliberately a **pure function** of a
//! `ClosedLoopStats`: tests assert on its output without touching the
//! global registry (which is shared across parallel test threads), and
//! `run_serve_bench` publishes exactly that snapshot — so the registry
//! counters match `ClosedLoopStats` by construction.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard};

use anyhow::{bail, Context, Result};

use crate::serve::ClosedLoopStats;
use crate::util::json::Json;

/// Log₂-bucketed histogram (non-atomic; the registry lock serializes
/// updates — use `serve::LatencyHistogram` for hot-path recording and
/// publish the summary here).
#[derive(Debug, Clone)]
pub struct HistData {
    buckets: Vec<u64>,
    count: u64,
}

impl HistData {
    fn new() -> HistData {
        HistData { buckets: vec![0; 64], count: 0 }
    }

    fn record(&mut self, v: u64) {
        let b = (64 - v.leading_zeros()) as usize; // 0 -> bucket 0
        self.buckets[b.min(63)] += 1;
        self.count += 1;
    }

    fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return if b == 0 { 0.0 } else { (1u64 << (b - 1)) as f64 * 1.5 };
            }
        }
        f64::MAX
    }
}

/// One registered metric.
#[derive(Debug, Clone)]
pub enum Metric {
    Counter(u64),
    Gauge(f64),
    Hist(HistData),
}

static REG: Mutex<BTreeMap<String, Metric>> = Mutex::new(BTreeMap::new());

fn lock_reg() -> MutexGuard<'static, BTreeMap<String, Metric>> {
    REG.lock().unwrap_or_else(|p| p.into_inner())
}

/// Add `delta` to counter `name` (registered on first use).
pub fn counter_add(name: &str, delta: u64) {
    let mut reg = lock_reg();
    match reg.get_mut(name) {
        Some(Metric::Counter(c)) => *c += delta,
        _ => {
            reg.insert(name.to_string(), Metric::Counter(delta));
        }
    }
}

/// Set counter `name` to an absolute value (publishing an externally
/// maintained atomic).
pub fn counter_set(name: &str, v: u64) {
    lock_reg().insert(name.to_string(), Metric::Counter(v));
}

/// Set gauge `name`.
pub fn gauge_set(name: &str, v: f64) {
    lock_reg().insert(name.to_string(), Metric::Gauge(v));
}

/// Record one observation into histogram `name`.
pub fn hist_record(name: &str, v: u64) {
    let mut reg = lock_reg();
    match reg.get_mut(name) {
        Some(Metric::Hist(h)) => h.record(v),
        _ => {
            let mut h = HistData::new();
            h.record(v);
            reg.insert(name.to_string(), Metric::Hist(h));
        }
    }
}

/// Clear every registered metric (tests; fresh pipeline runs).
pub fn reset() {
    lock_reg().clear();
}

/// Sorted names of every registered metric.
pub fn names() -> Vec<String> {
    lock_reg().keys().cloned().collect()
}

fn metric_json(m: &Metric) -> Json {
    match m {
        Metric::Counter(c) => Json::Num(*c as f64),
        Metric::Gauge(g) => Json::Num(if g.is_finite() { *g } else { 0.0 }),
        Metric::Hist(h) => Json::Obj(BTreeMap::from([
            ("count".to_string(), Json::Num(h.count as f64)),
            ("p50".to_string(), Json::Num(h.percentile(0.50))),
            ("p99".to_string(), Json::Num(h.percentile(0.99))),
        ])),
    }
}

/// JSON snapshot of the whole registry: `{name: value, ...}` with
/// histograms as `{count, p50, p99}` objects.  Keys are sorted
/// (BTreeMap), so snapshots of the same run are byte-stable.
pub fn snapshot() -> Json {
    let reg = lock_reg();
    Json::Obj(reg.iter().map(|(k, m)| (k.clone(), metric_json(m))).collect())
}

/// Write [`snapshot`] to `path` (the `gs stats` input format).
pub fn snapshot_to_file(path: &str) -> Result<()> {
    let text = snapshot().to_string_pretty();
    std::fs::write(path, text + "\n").with_context(|| format!("write metrics snapshot {path}"))
}

fn render_value(v: &Json) -> String {
    match v {
        Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => format!("{}", *n as i64),
        Json::Num(n) => format!("{n:.3}"),
        Json::Obj(m) => {
            let f = |k: &str| m.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            format!("count {} p50 {:.0} p99 {:.0}", f("count") as u64, f("p50"), f("p99"))
        }
        other => other.to_string_pretty(),
    }
}

/// Render a snapshot (the [`snapshot`] JSON shape) as an aligned
/// two-column table — the `gs stats` / `--stats` report.
pub fn render_table(snap: &Json) -> String {
    let Some(m) = snap.as_obj() else {
        return String::from("(not a metrics snapshot: expected a JSON object)\n");
    };
    if m.is_empty() {
        return String::from("(no metrics registered)\n");
    }
    let width = m.keys().map(|k| k.len()).max().unwrap_or(0);
    let mut out = String::new();
    for (k, v) in m {
        out.push_str(&format!("{k:<width$}  {}\n", render_value(v)));
    }
    out
}

/// Load a snapshot file and render it (`gs stats PATH`).  Accepts
/// either a bare [`snapshot`] object or a `--report` pipeline outcome
/// (rendering its `metrics` sub-object).
pub fn render_file(path: &str) -> Result<String> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("read metrics snapshot {path}"))?;
    let snap = Json::parse(&text).with_context(|| format!("parse metrics snapshot {path}"))?;
    if snap.as_obj().is_none() {
        bail!("{path}: metrics snapshot must be a JSON object");
    }
    match snap.get("metrics") {
        Some(m) if m.as_obj().is_some() => Ok(render_table(m)),
        _ => Ok(render_table(&snap)),
    }
}

/// Pure per-arm metrics snapshot of one closed-loop serve run: the
/// exact name/value pairs `run_serve_bench` publishes for that arm
/// under `serve.<arm>.` — counters first (pool-size-invariant except
/// where timing-dependent, see docs/OBSERVABILITY.md), then derived
/// gauges.  Pure so tests can assert equality with `ClosedLoopStats`
/// without racing other tests for the global registry.
pub fn closed_loop_snapshot(prefix: &str, s: &ClosedLoopStats) -> Vec<(String, Metric)> {
    let c = |k: &str, v: u64| (format!("{prefix}.{k}"), Metric::Counter(v));
    let g = |k: &str, v: f64| (format!("{prefix}.{k}"), Metric::Gauge(v));
    vec![
        c("coalesced", s.coalesced),
        c("deadline_misses", s.deadline_misses),
        c("hits", s.hits),
        c("misses", s.misses),
        c("requests", s.requests as u64),
        c("restarts", s.restarts),
        c("retries", s.retries),
        c("shed", s.shed),
        g("hit_rate", s.hit_rate),
        g("p50_us", s.p50_us),
        g("p99_us", s.p99_us),
        g("rps", s.rps),
        g("wall_s", s.wall_s),
    ]
}

/// Publish a pre-built snapshot (e.g. [`closed_loop_snapshot`]) into
/// the global registry.
pub fn publish(entries: Vec<(String, Metric)>) {
    let mut reg = lock_reg();
    for (k, m) in entries {
        reg.insert(k, m);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_hists_snapshot() {
        // Unique prefix: the registry is global and tests run in
        // parallel within this binary.
        let p = "test.metrics_unit";
        counter_add(&format!("{p}.c"), 2);
        counter_add(&format!("{p}.c"), 3);
        counter_set(&format!("{p}.abs"), 41);
        gauge_set(&format!("{p}.g"), 1.5);
        for v in [1u64, 2, 100, 100, 100] {
            hist_record(&format!("{p}.h"), v);
        }
        let snap = snapshot();
        assert_eq!(snap.get(&format!("{p}.c")).and_then(Json::as_f64), Some(5.0));
        assert_eq!(snap.get(&format!("{p}.abs")).and_then(Json::as_f64), Some(41.0));
        assert_eq!(snap.get(&format!("{p}.g")).and_then(Json::as_f64), Some(1.5));
        let h = snap.get(&format!("{p}.h")).unwrap();
        assert_eq!(h.get("count").and_then(Json::as_f64), Some(5.0));
        assert!(h.get("p99").and_then(Json::as_f64).unwrap() >= 64.0);
        let table = render_table(&snap);
        assert!(table.contains(&format!("{p}.c")));
        assert!(table.lines().any(|l| l.ends_with(" 5")));
    }

    #[test]
    fn closed_loop_snapshot_is_exact_and_pure() {
        let s = ClosedLoopStats {
            requests: 100,
            wall_s: 0.5,
            rps: 200.0,
            p50_us: 10.0,
            p99_us: 90.0,
            hit_rate: 0.25,
            hits: 25,
            misses: 75,
            coalesced: 3,
            restarts: 1,
            retries: 2,
            shed: 0,
            deadline_misses: 0,
        };
        let snap = closed_loop_snapshot("serve.test", &s);
        let get = |k: &str| {
            snap.iter()
                .find(|(n, _)| n == &format!("serve.test.{k}"))
                .map(|(_, m)| m.clone())
                .unwrap()
        };
        for (k, want) in
            [("hits", 25u64), ("misses", 75), ("coalesced", 3), ("restarts", 1), ("retries", 2)]
        {
            match get(k) {
                Metric::Counter(v) => assert_eq!(v, want, "{k}"),
                other => panic!("{k} is not a counter: {other:?}"),
            }
        }
        match get("hit_rate") {
            Metric::Gauge(v) => assert_eq!(v, 0.25),
            other => panic!("hit_rate is not a gauge: {other:?}"),
        }
        // Names are sorted-within-kind and stable.
        let names: Vec<&str> = snap.iter().map(|(n, _)| n.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted, "snapshot names must come out sorted");
    }

    #[test]
    fn render_file_round_trip() {
        let p = "test.metrics_file";
        counter_set(&format!("{p}.total"), 7);
        let dir = std::env::temp_dir().join(format!("gs_metrics_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.json");
        let ps = path.to_str().unwrap();
        snapshot_to_file(ps).unwrap();
        let rendered = render_file(ps).unwrap();
        assert!(rendered.contains(&format!("{p}.total")));
        assert!(render_file(dir.join("missing.json").to_str().unwrap()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
