//! Counting allocator: wraps the system allocator and counts
//! allocations + bytes requested.
//!
//! The type is always available (benches construct their own
//! instances), but it only becomes the `gs` binary's global allocator
//! under the `count-alloc` cargo feature (`src/main.rs`), because the
//! counting hooks cost an atomic RMW per allocation:
//!
//! ```bash
//! cargo run --release --features count-alloc -- run --conf F --stats
//! ```
//!
//! With the feature on, the pipeline publishes `alloc.count` /
//! `alloc.bytes` into the metrics registry at end of run — the
//! allocation profile of a whole pipeline in one counter pair.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Total allocation calls (alloc + realloc) since process start.
pub static ALLOCS: AtomicU64 = AtomicU64::new(0);
/// Total bytes requested (alloc sizes + realloc new sizes).
pub static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// `(allocations, bytes)` so far — `(0, 0)` unless a
/// [`CountingAlloc`] is installed as the global allocator.
pub fn alloc_counts() -> (u64, u64) {
    (ALLOCS.load(Ordering::Relaxed), ALLOC_BYTES.load(Ordering::Relaxed))
}

/// System allocator with counting hooks.  Install with:
///
/// ```ignore
/// #[global_allocator]
/// static GLOBAL: CountingAlloc = CountingAlloc;
/// ```
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_are_monotone() {
        let (a0, b0) = alloc_counts();
        // Without the feature these stay zero; with it they only grow.
        let v: Vec<u8> = Vec::with_capacity(4096);
        drop(v);
        let (a1, b1) = alloc_counts();
        assert!(a1 >= a0 && b1 >= b0);
    }
}
