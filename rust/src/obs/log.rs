//! Leveled structured logger: `gs_debug!` / `gs_info!` / `gs_warn!`
//! print `[subsystem] message` lines to stderr, filtered by the
//! `GS_LOG` environment variable (`debug` | `info` | `warn`; default
//! `info`).
//!
//! This replaces the ad-hoc `eprintln!("[nc] ...")` calls that were
//! scattered through the trainers and loader.  The line format is
//! byte-identical to what those sites printed (same `[subsystem]`
//! prefixes, same bodies), so anything grepping trainer output keeps
//! working — the logger only adds the ability to silence it
//! (`GS_LOG=warn`) or turn on debug detail (`GS_LOG=debug`).
//!
//! Every `gs_info!` line also lands in the trace as an instant event
//! named `log.<level>` when tracing is enabled, so log lines line up
//! with spans on the chrome://tracing timeline.

use std::sync::OnceLock;

/// Log severity, ordered `Debug < Info < Warn`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
        }
    }
}

/// The process log threshold, parsed from `GS_LOG` once (first use).
/// Unknown values fall back to `info` — a typo must not silence
/// warnings.
pub fn level() -> Level {
    static LEVEL: OnceLock<Level> = OnceLock::new();
    *LEVEL.get_or_init(|| match std::env::var("GS_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        _ => Level::Info,
    })
}

/// Whether a message at `l` passes the threshold.
#[inline]
pub fn log_enabled(l: Level) -> bool {
    l >= level()
}

/// Print one `[subsystem] message` line (the macro backend).
pub fn log(l: Level, subsystem: &str, msg: std::fmt::Arguments<'_>) {
    if !log_enabled(l) {
        return;
    }
    eprintln!("[{subsystem}] {msg}");
    if crate::obs::trace::enabled() {
        crate::obs::trace::instant(
            match l {
                Level::Debug => "log.debug",
                Level::Info => "log.info",
                Level::Warn => "log.warn",
            },
            Vec::new(),
        );
    }
}

/// `[subsystem]`-prefixed debug line (shown only under `GS_LOG=debug`).
#[macro_export]
macro_rules! gs_debug {
    ($sub:expr, $($arg:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Debug, $sub, format_args!($($arg)*))
    };
}

/// `[subsystem]`-prefixed info line (the default trainer/loader
/// progress output; silence with `GS_LOG=warn`).
#[macro_export]
macro_rules! gs_info {
    ($sub:expr, $($arg:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Info, $sub, format_args!($($arg)*))
    };
}

/// `[subsystem]`-prefixed warning line (always shown).
#[macro_export]
macro_rules! gs_warn {
    ($sub:expr, $($arg:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Warn, $sub, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_default() {
        assert!(Level::Debug < Level::Info && Level::Info < Level::Warn);
        // Default threshold (no GS_LOG in the test env) is Info.
        if std::env::var("GS_LOG").is_err() {
            assert_eq!(level(), Level::Info);
            assert!(log_enabled(Level::Warn));
            assert!(log_enabled(Level::Info));
            assert!(!log_enabled(Level::Debug));
        }
        assert_eq!(Level::Info.name(), "info");
        // Smoke the macros (output goes to stderr; must not panic).
        gs_debug!("test", "debug {}", 1);
        gs_info!("test", "info {}", 2);
        gs_warn!("test", "warn {}", 3);
    }
}
