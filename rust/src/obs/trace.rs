//! Scoped span/event tracer: `span!`/`event!` record into per-thread
//! buffers and drain to a JSONL trace file at end of run.
//!
//! Design goals, in order:
//!
//! 1. **Free when off.**  The fast path is one relaxed atomic load
//!    (`enabled()`); the `span!`/`event!` macros do not evaluate their
//!    field expressions, allocate, or touch thread-locals when tracing
//!    is disabled (`benches/serve.rs` pins the disabled cost).
//! 2. **Determinism-neutral.**  Recording never takes a lock on a hot
//!    path (events buffer thread-locally and flush in amortized
//!    batches), never consumes RNG state, and never changes control
//!    flow — `rust/tests/obs.rs` asserts replies are bit-identical
//!    with tracing on and off.
//! 3. **Structurally deterministic output.**  The drained event stream
//!    is sorted by `(ts, tid, name)`, so two runs of the same workload
//!    produce the same span names/fields modulo timestamps.
//!
//! One JSONL line per event, chrome://tracing "Trace Event Format"
//! compatible (`ph: "X"` complete spans, `ph: "i"` instants, µs
//! timestamps relative to process start):
//!
//! ```json
//! {"name":"serve.batch.forward","ph":"X","pid":1,"tid":3,"ts":1042,"dur":187,"args":{"seq":7,"rows":32}}
//! ```
//!
//! `obs.chrome_trace` writes the same events wrapped in a JSON array,
//! loadable directly by chrome://tracing / Perfetto.  The schema is
//! documented in docs/OBSERVABILITY.md and machine-checked by
//! [`validate_jsonl`] (exposed as `gs trace-check`, gated in
//! scripts/test.sh).

use std::cell::RefCell;
use std::fmt::Write as _;
use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Typed span/event field value (`key=value` pairs in `args`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FieldValue {
    U64(u64),
    F64(f64),
    Str(&'static str),
}

impl From<u64> for FieldValue {
    fn from(v: u64) -> FieldValue {
        FieldValue::U64(v)
    }
}
impl From<u32> for FieldValue {
    fn from(v: u32) -> FieldValue {
        FieldValue::U64(v as u64)
    }
}
impl From<usize> for FieldValue {
    fn from(v: usize) -> FieldValue {
        FieldValue::U64(v as u64)
    }
}
impl From<f64> for FieldValue {
    fn from(v: f64) -> FieldValue {
        FieldValue::F64(v)
    }
}
impl From<f32> for FieldValue {
    fn from(v: f32) -> FieldValue {
        FieldValue::F64(v as f64)
    }
}
impl From<&'static str> for FieldValue {
    fn from(v: &'static str) -> FieldValue {
        FieldValue::Str(v)
    }
}
impl From<bool> for FieldValue {
    fn from(v: bool) -> FieldValue {
        FieldValue::U64(v as u64)
    }
}

/// One recorded complete span (`ph: "X"`) or instant event (`ph: "i"`).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: &'static str,
    pub tid: u64,
    /// Microseconds since the tracer epoch (process-relative).
    pub ts_us: u64,
    pub dur_us: u64,
    pub instant: bool,
    pub fields: Vec<(&'static str, FieldValue)>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// Buffered thread-local events flush to the global sink every
/// `FLUSH_AT` records (and on thread exit via `Drop`), so steady-state
/// recording takes the sink lock ~once per thousand events.
const FLUSH_AT: usize = 1024;

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Whether tracing is recording.  One relaxed load — the only cost a
/// disabled `span!`/`event!` site pays.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the tracer on or off.  Pins the epoch first so `ts` values are
/// monotonic from the first enable.  Enabling is idempotent; the
/// pipeline only ever *enables* (never disables a tracer some other
/// component turned on).
pub fn set_enabled(on: bool) {
    let _ = epoch();
    ENABLED.store(on, Ordering::Relaxed);
}

fn lock_sink() -> MutexGuard<'static, Vec<TraceEvent>> {
    SINK.lock().unwrap_or_else(|p| p.into_inner())
}

struct LocalBuf {
    events: Vec<TraceEvent>,
}

impl LocalBuf {
    fn push(&mut self, ev: TraceEvent) {
        self.events.push(ev);
        if self.events.len() >= FLUSH_AT {
            self.flush();
        }
    }

    fn flush(&mut self) {
        if !self.events.is_empty() {
            lock_sink().append(&mut self.events);
        }
    }
}

impl Drop for LocalBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    static LOCAL: RefCell<LocalBuf> = const { RefCell::new(LocalBuf { events: Vec::new() }) };
}

/// Stable small integer id for the current thread (assigned on first
/// trace from that thread; `0` only during thread teardown).
#[inline]
pub fn current_tid() -> u64 {
    TID.try_with(|t| *t).unwrap_or(0)
}

fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

fn record(ev: TraceEvent) {
    let mut ev = Some(ev);
    let pushed = LOCAL
        .try_with(|l| {
            if let (Ok(mut buf), Some(e)) = (l.try_borrow_mut(), ev.take()) {
                buf.push(e);
                true
            } else {
                false
            }
        })
        .unwrap_or(false);
    if !pushed {
        // Thread-local destroyed (thread teardown) — record directly.
        if let Some(e) = ev.take() {
            lock_sink().push(e);
        }
    }
}

/// Record an instant event (`ph: "i"`).  Prefer the [`event!`] macro,
/// which skips field evaluation when tracing is off.
pub fn instant(name: &'static str, fields: Vec<(&'static str, FieldValue)>) {
    if !enabled() {
        return;
    }
    record(TraceEvent { name, tid: current_tid(), ts_us: now_us(), dur_us: 0, instant: true, fields });
}

/// RAII guard for a complete span: records `(start, duration)` when
/// dropped.  Constructed by the [`span!`] macro — [`SpanGuard::off`]
/// is the zero-cost disabled arm.
pub struct SpanGuard {
    active: Option<(&'static str, u64, Vec<(&'static str, FieldValue)>)>,
}

impl SpanGuard {
    /// Disabled guard: no allocation, nothing recorded on drop.
    #[inline]
    pub fn off() -> SpanGuard {
        SpanGuard { active: None }
    }

    /// Start a span now (caller has already checked [`enabled`]).
    pub fn begin_on(name: &'static str, fields: Vec<(&'static str, FieldValue)>) -> SpanGuard {
        SpanGuard { active: Some((name, now_us(), fields)) }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((name, start, fields)) = self.active.take() {
            if !enabled() {
                return; // tracing turned off mid-span: drop silently
            }
            let end = now_us();
            record(TraceEvent {
                name,
                tid: current_tid(),
                ts_us: start,
                dur_us: end.saturating_sub(start),
                instant: false,
                fields,
            });
        }
    }
}

/// Open a scoped span; the returned guard records it on drop.
///
/// ```ignore
/// let _s = span!("serve.batch.forward", seq, rows = seeds.len());
/// ```
///
/// Fields are `key = expr` pairs (bare `ident` is shorthand for
/// `ident = ident`); values coerce through `FieldValue::from`
/// (unsigned ints, floats, `&'static str`, bool).  When tracing is
/// disabled the field expressions are **not evaluated**.
#[macro_export]
macro_rules! span {
    ($name:expr $(,)?) => {
        if $crate::obs::trace::enabled() {
            $crate::obs::trace::SpanGuard::begin_on($name, Vec::new())
        } else {
            $crate::obs::trace::SpanGuard::off()
        }
    };
    ($name:expr, $($k:ident = $v:expr),+ $(,)?) => {
        if $crate::obs::trace::enabled() {
            $crate::obs::trace::SpanGuard::begin_on(
                $name,
                vec![$((stringify!($k), $crate::obs::trace::FieldValue::from($v))),+],
            )
        } else {
            $crate::obs::trace::SpanGuard::off()
        }
    };
    ($name:expr, $($k:ident),+ $(,)?) => {
        $crate::span!($name, $($k = $k),+)
    };
}

/// Record an instant event (a point in time, no duration).  Same field
/// syntax and disabled-cost contract as [`span!`].
#[macro_export]
macro_rules! event {
    ($name:expr $(,)?) => {
        if $crate::obs::trace::enabled() {
            $crate::obs::trace::instant($name, Vec::new());
        }
    };
    ($name:expr, $($k:ident = $v:expr),+ $(,)?) => {
        if $crate::obs::trace::enabled() {
            $crate::obs::trace::instant(
                $name,
                vec![$((stringify!($k), $crate::obs::trace::FieldValue::from($v))),+],
            );
        }
    };
    ($name:expr, $($k:ident),+ $(,)?) => {
        $crate::event!($name, $($k = $k),+)
    };
}

/// Drain every recorded event, sorted by `(ts, tid, name)` for
/// structural determinism.  Flushes the calling thread's local buffer;
/// other threads' buffers flush when those threads exit (scoped worker
/// threads have all joined by the time the pipeline drains).
pub fn drain() -> Vec<TraceEvent> {
    let _ = LOCAL.try_with(|l| {
        if let Ok(mut buf) = l.try_borrow_mut() {
            buf.flush();
        }
    });
    let mut evs = std::mem::take(&mut *lock_sink());
    evs.sort_by(|a, b| (a.ts_us, a.tid, a.name).cmp(&(b.ts_us, b.tid, b.name)));
    evs
}

fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
}

fn push_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        let _ = write!(out, "{v}");
    } else {
        out.push('0'); // JSON has no NaN/Inf; a zero keeps the line parseable
    }
}

/// One compact JSON line for `ev` (the JSONL / chrome trace record).
pub fn event_json(ev: &TraceEvent) -> String {
    let mut s = String::with_capacity(96);
    s.push_str("{\"name\":\"");
    escape_into(&mut s, ev.name);
    s.push_str("\",\"ph\":\"");
    s.push_str(if ev.instant { "i" } else { "X" });
    let _ = write!(s, "\",\"pid\":1,\"tid\":{},\"ts\":{},\"dur\":{},\"args\":{{", ev.tid, ev.ts_us, ev.dur_us);
    for (i, (k, v)) in ev.fields.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push('"');
        escape_into(&mut s, k);
        s.push_str("\":");
        match v {
            FieldValue::U64(u) => {
                let _ = write!(s, "{u}");
            }
            FieldValue::F64(f) => push_f64(&mut s, *f),
            FieldValue::Str(t) => {
                s.push('"');
                escape_into(&mut s, t);
                s.push('"');
            }
        }
    }
    s.push_str("}}");
    s
}

/// Write `events` as a JSONL trace (one event per line).
pub fn write_jsonl(path: &str, events: &[TraceEvent]) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create trace file {path}"))?;
    let mut w = std::io::BufWriter::new(f);
    for ev in events {
        writeln!(w, "{}", event_json(ev)).context("write trace line")?;
    }
    w.flush().context("flush trace file")?;
    Ok(())
}

/// Write `events` as one chrome://tracing-loadable JSON array.
pub fn write_chrome(path: &str, events: &[TraceEvent]) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create chrome trace {path}"))?;
    let mut w = std::io::BufWriter::new(f);
    w.write_all(b"[").context("write chrome trace")?;
    for (i, ev) in events.iter().enumerate() {
        if i > 0 {
            w.write_all(b",\n ").context("write chrome trace")?;
        }
        w.write_all(event_json(ev).as_bytes()).context("write chrome trace")?;
    }
    w.write_all(b"]\n").context("write chrome trace")?;
    w.flush().context("flush chrome trace")?;
    Ok(())
}

const SCHEMA_KEYS: [&str; 7] = ["args", "dur", "name", "ph", "pid", "tid", "ts"];

fn check_line(line: &str) -> Result<()> {
    let v = Json::parse(line)?;
    let Json::Obj(m) = &v else { bail!("not a JSON object") };
    let keys: Vec<&str> = m.keys().map(|k| k.as_str()).collect();
    if keys != SCHEMA_KEYS {
        bail!("keys {keys:?} != documented schema {SCHEMA_KEYS:?}");
    }
    match m.get("name") {
        Some(Json::Str(s)) if !s.is_empty() => {}
        _ => bail!("\"name\" must be a non-empty string"),
    }
    match m.get("ph") {
        Some(Json::Str(s)) if s == "X" || s == "i" => {}
        _ => bail!("\"ph\" must be \"X\" or \"i\""),
    }
    for k in ["pid", "tid", "ts", "dur"] {
        match m.get(k) {
            Some(Json::Num(n)) if *n >= 0.0 && n.fract() == 0.0 => {}
            _ => bail!("\"{k}\" must be a non-negative integer"),
        }
    }
    match m.get("args") {
        Some(Json::Obj(_)) => {}
        _ => bail!("\"args\" must be an object"),
    }
    Ok(())
}

/// Validate a JSONL trace against the documented schema
/// (docs/OBSERVABILITY.md): every non-empty line must parse as a JSON
/// object with exactly the keys `name/ph/pid/tid/ts/dur/args`, `ph` in
/// `{"X","i"}`, integer timestamps and an `args` object.  Returns the
/// number of validated events — the `gs trace-check` subcommand, gated
/// in scripts/test.sh.
pub fn validate_jsonl(path: &str) -> Result<usize> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("read trace file {path}"))?;
    let mut n = 0usize;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        check_line(line).with_context(|| format!("{path}:{}: invalid trace line", i + 1))?;
        n += 1;
    }
    if n == 0 {
        bail!("{path}: no trace events");
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The tracer is process-global; tests that toggle it serialize on
    // this (same pattern as rust/tests/obs.rs).
    static GATE: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_span_records_nothing_and_skips_fields() {
        let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
        set_enabled(false);
        drain();
        let mut evaluated = false;
        {
            let _s = span!("test.disabled", x = {
                evaluated = true;
                1u64
            });
        }
        event!("test.disabled.event");
        assert!(!evaluated, "disabled span! must not evaluate field exprs");
        assert!(drain().is_empty());
    }

    #[test]
    fn spans_round_trip_through_jsonl() {
        let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
        set_enabled(false);
        drain();
        set_enabled(true);
        {
            let _s = span!("test.outer", seq = 7u64, kind = "unit");
            event!("test.mark", ok = true);
        }
        set_enabled(false);
        let evs = drain();
        assert_eq!(evs.len(), 2);
        let dir = std::env::temp_dir().join(format!("gs_trace_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.jsonl");
        let ps = p.to_str().unwrap();
        write_jsonl(ps, &evs).unwrap();
        assert_eq!(validate_jsonl(ps).unwrap(), 2);
        let text = std::fs::read_to_string(ps).unwrap();
        assert!(text.contains("\"name\":\"test.outer\""));
        assert!(text.contains("\"seq\":7"));
        assert!(text.contains("\"kind\":\"unit\""));
        assert!(text.contains("\"ph\":\"i\""));
        let cp = dir.join("t.chrome.json");
        write_chrome(cp.to_str().unwrap(), &evs).unwrap();
        let arr = Json::parse(&std::fs::read_to_string(&cp).unwrap()).unwrap();
        match arr {
            Json::Arr(v) => assert_eq!(v.len(), 2),
            other => panic!("chrome trace is not an array: {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn validator_rejects_malformed_lines() {
        let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
        let dir = std::env::temp_dir().join(format!("gs_trace_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cases = [
            "not json",
            "{\"name\":\"x\",\"ph\":\"X\",\"pid\":1,\"tid\":1,\"ts\":0,\"dur\":0}", // no args
            "{\"args\":{},\"dur\":0,\"name\":\"x\",\"ph\":\"Q\",\"pid\":1,\"tid\":1,\"ts\":0}", // bad ph
            "{\"args\":{},\"dur\":-1,\"name\":\"x\",\"ph\":\"X\",\"pid\":1,\"tid\":1,\"ts\":0}", // neg dur
            "{\"args\":{},\"dur\":0,\"name\":\"\",\"ph\":\"X\",\"pid\":1,\"tid\":1,\"ts\":0}", // empty name
        ];
        for (i, c) in cases.iter().enumerate() {
            let p = dir.join(format!("bad{i}.jsonl"));
            std::fs::write(&p, format!("{c}\n")).unwrap();
            assert!(validate_jsonl(p.to_str().unwrap()).is_err(), "case {i} must fail: {c}");
        }
        let empty = dir.join("empty.jsonl");
        std::fs::write(&empty, "\n").unwrap();
        assert!(validate_jsonl(empty.to_str().unwrap()).is_err(), "empty trace must fail");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn non_finite_fields_stay_parseable() {
        let ev = TraceEvent {
            name: "test.nan",
            tid: 1,
            ts_us: 0,
            dur_us: 0,
            instant: true,
            fields: vec![("bad", FieldValue::F64(f64::NAN)), ("inf", FieldValue::F64(f64::INFINITY))],
        };
        let line = event_json(&ev);
        check_line(&line).unwrap();
    }
}
