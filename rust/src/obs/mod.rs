//! Unified observability layer: structured tracing, a process-wide
//! metrics registry, a leveled logger and allocation counters — the
//! telemetry substrate for both training and serving
//! (docs/OBSERVABILITY.md).
//!
//! * [`trace`] — scoped spans (`span!("serve.batch.forward", seq)`)
//!   and instant events (`event!`) recorded into per-thread buffers
//!   and drained to a JSONL trace (`obs.trace` / `--trace PATH`) plus
//!   a chrome://tracing export (`obs.chrome_trace`).  One relaxed
//!   atomic load when disabled; determinism-neutral when enabled.
//! * [`metrics`] — one registry of named counters/gauges/histograms
//!   that serving (`ServeMetrics`, cache, supervision, refresh),
//!   training (per-epoch loss/throughput), the distributed engine
//!   (`TrafficCounters`) and the pipeline (`stage_secs`) all publish
//!   into; snapshotable as JSON (`--stats`, `gs stats PATH`).
//! * [`log`] — `gs_debug!`/`gs_info!`/`gs_warn!` leveled `[subsystem]`
//!   lines filtered by `GS_LOG` (default `info`, byte-compatible with
//!   the old ad-hoc `eprintln!` trainer output).
//! * [`alloc`] — a counting allocator, installed for the `gs` binary
//!   under the `count-alloc` cargo feature.
//!
//! Lifecycle: `config::Pipeline::run` calls [`init`] before its first
//! stage (enabling the tracer iff a trace output is configured — it
//! never *disables* a tracer something else turned on) and [`finish`]
//! after its last, which drains the trace to the configured files.
//! Everything is off by default: a run without `obs.*` keys records
//! nothing and pays one atomic load per instrumentation site
//! (`benches/serve.rs` pins the disabled cost).

pub mod alloc;
pub mod log;
pub mod metrics;
pub mod trace;

pub use alloc::{alloc_counts, CountingAlloc};
pub use log::{log_enabled, Level};
pub use metrics::{closed_loop_snapshot, Metric};
pub use trace::{validate_jsonl, FieldValue, SpanGuard, TraceEvent};

use anyhow::Result;

use crate::config::ObsCfg;

/// Arm the observability layer for a pipeline run: enables the tracer
/// iff `cfg` names a trace output.  Enable-only by design — parallel
/// tests and nested runs must never turn off a tracer they didn't
/// start.
pub fn init(cfg: &ObsCfg) {
    if cfg.trace.is_some() || cfg.chrome_trace.is_some() {
        trace::set_enabled(true);
    }
}

/// Drain recorded trace events to the configured outputs (no-op when
/// no trace output is configured).  Returns the number of events
/// written.
pub fn finish(cfg: &ObsCfg) -> Result<usize> {
    if cfg.trace.is_none() && cfg.chrome_trace.is_none() {
        return Ok(0);
    }
    let events = trace::drain();
    if let Some(path) = &cfg.trace {
        trace::write_jsonl(path, &events)?;
    }
    if let Some(path) = &cfg.chrome_trace {
        trace::write_chrome(path, &events)?;
    }
    Ok(events.len())
}
