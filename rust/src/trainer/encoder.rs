//! The shared GNN-encoder forward/backward path.
//!
//! Every GNN training loop in the crate drives the same per-batch
//! sequence against an assembled block batch: fill the deferred
//! learnable-embedding rows (the sparse half of the encoder, shared
//! across tasks through `dist::EmbTable`), execute the AOT train step,
//! then scatter the step's `grad_lemb` back onto the tables.  That
//! sequence used to live copy-pasted inside the NC and LP trainers;
//! [`EncoderStep`] is the one implementation both (and the multi-task
//! trainer's per-task heads) now call, so a combined run pays for the
//! encoder machinery once and single-task runs are thin wrappers over
//! the same code — with the exact same operation order, so metrics
//! stay bit-identical to the pre-refactor trainers.
//!
//! What is shared vs. per-head in this architecture: the *sparse*
//! encoder state (learnable embedding tables + text embeddings) lives
//! in the dataset's `DistEngine` and is updated in place by every
//! head that touches it; the *dense* artifact state (GNN weights +
//! Adam moments) is per-head device state owned by each `TrainState`.

use anyhow::Result;

use crate::dataloader::{apply_lemb_grads, fill_lemb, GsDataset, LembTouch};
use crate::runtime::{ArtifactSpec, Runtime, StepOut, Tensor, TrainState};

/// The shared encoder forward/backward step over an assembled batch.
#[derive(Debug, Clone, Copy)]
pub struct EncoderStep {
    /// Learnable-embedding width of the artifact (0 = no lemb input,
    /// the sparse update is skipped entirely).
    pub ldim: usize,
}

impl EncoderStep {
    /// Read the lemb width off the artifact's batch spec.
    pub fn from_spec(spec: &ArtifactSpec) -> EncoderStep {
        EncoderStep { ldim: spec.batch_spec("lemb").map(|t| t.shape[1]).unwrap_or(0) }
    }

    /// One train step: fill the deferred learnable-embedding rows of
    /// `batch` from the current tables (attributed to partition
    /// `worker`), run the artifact step with `scalars`, and apply
    /// `grad_lemb` back via sparse Adam at `scalars[0]` — the learning
    /// rate by manifest convention, so the dense and sparse halves of
    /// the encoder can never drift to different rates.  Must run on
    /// the consuming thread only — it reads embedding rows that
    /// concurrent prefetch workers deliberately leave deferred.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        rt: &Runtime,
        ds: &GsDataset,
        st: &mut TrainState,
        scalars: &[f32],
        batch: &mut Vec<Tensor>,
        touch: &LembTouch,
        worker: u32,
    ) -> Result<StepOut> {
        fill_lemb(ds, batch, touch, worker)?;
        let out = st.step(rt, scalars, batch)?;
        if let (Some(g), true) = (&out.grad_lemb, self.ldim > 0) {
            apply_lemb_grads(&ds.engine, touch, g, self.ldim, scalars[0]);
        }
        Ok(out)
    }
}
