//! GNN → LM distillation (paper §3.3.3, Table 5).
//!
//! A trained GNN teacher produces node embeddings; a graph-free student
//! LM ("DistilBERT": 1 transformer layer) is trained with MSE to match
//! them.  Evaluation follows the paper: freeze each student, train an
//! MLP probe on its embeddings, compare probe accuracy.

use anyhow::{anyhow, bail, Result};

use crate::dataloader::{
    batch_seed, fill_lemb, run_pipeline_pooled, BatchFactory, GsDataset, IdChunks, LembTouch,
    Split,
    TokenStore,
};
use crate::runtime::{ArtifactSpec, InferSession, Runtime, Tensor, TrainState};
use crate::sampling::{BlockShape, EdgeExclusion};
use crate::trainer::TrainOptions;
use crate::util::{FxHashMap, Rng};

/// Per-epoch node subsample for distillation (shared by the
/// standalone trainer and the multi-task distill head).
pub const DISTILL_EPOCH_SUBSAMPLE: usize = 2048;

/// Shapes a distillation run derives from its artifacts: student rows
/// `b` × seq len `s`, embedding width `h`, teacher batch cap `bt`.
#[derive(Debug, Clone, Copy)]
pub struct DistillDims {
    pub b: usize,
    pub s: usize,
    pub h: usize,
    pub bt: usize,
}

impl DistillDims {
    /// Derive from the student train spec + teacher emb spec (also
    /// yields the teacher's block shape).  The teacher's embedding
    /// width must match the student's MSE target.
    pub fn derive(spec: &ArtifactSpec, tspec: &ArtifactSpec) -> Result<(DistillDims, BlockShape)> {
        let tok = spec
            .batch_spec("tokens")
            .ok_or_else(|| anyhow!("distill artifact '{}' has no tokens input", spec.file))?;
        let (b, s) = (tok.shape[0], tok.shape[1]);
        let h = spec
            .batch_spec("teacher")
            .ok_or_else(|| anyhow!("distill artifact '{}' has no teacher input", spec.file))?
            .shape[1];
        let tshape = BlockShape::from_spec(tspec)
            .ok_or_else(|| anyhow!("teacher artifact '{}' has no block config", tspec.file))?;
        let bt = tspec.cfg_usize("batch").unwrap_or(tshape.num_targets());
        let th = tspec.outputs[0].shape[1];
        if th != h {
            bail!("teacher embedding dim {th} must match the student target {h}");
        }
        Ok((DistillDims { b, s, h, bt }, tshape))
    }
}

/// One distillation work item: the teacher's GNN input blocks for a
/// chunk of node ids plus the student's padded token batch.  Built on
/// prefetch workers with learnable-embedding rows *deferred* (like
/// every other trainer batch — a multi-task run's NC/LP heads mutate
/// the shared tables on the consuming thread, so workers must never
/// read them); the fill + teacher forward + student step run on the
/// consuming thread ([`distill_student_step`]).
#[derive(Debug, Clone, PartialEq)]
pub struct DistillBatch {
    /// Assembled teacher blocks with their deferred-lemb touch lists
    /// and real (unpadded) row counts.
    pub tbatches: Vec<(Vec<Tensor>, LembTouch, usize)>,
    pub tokens: Vec<i32>,
    pub lmask: Vec<f32>,
}

/// Build one distillation batch: teacher GNN blocks for the chunk
/// (sub-chunked to the teacher's batch cap) + student tokens.
#[allow(clippy::too_many_arguments)]
pub fn build_distill_batch(
    f: &mut BatchFactory,
    store: &TokenStore,
    nt: usize,
    chunk: &[u32],
    rng: &mut Rng,
    tshape: &BlockShape,
    tspec: &ArtifactSpec,
    dims: &DistillDims,
) -> Result<DistillBatch> {
    let (b, s, bt) = (dims.b, dims.s, dims.bt);
    let mut tbatches = vec![];
    for sub in chunk.chunks(bt) {
        let seeds: Vec<(u32, u32)> = sub.iter().map(|&i| (nt as u32, i)).collect();
        let (batch, touch) =
            f.sample_assemble(&seeds, tshape, tspec, rng, 0, &EdgeExclusion::new(), true)?;
        tbatches.push((batch, touch, sub.len()));
    }
    let mut tokens = vec![0i32; b * s];
    let mut lmask = vec![0.0f32; b];
    for (i, &id) in chunk.iter().enumerate() {
        tokens[i * s..(i + 1) * s].copy_from_slice(store.row(id));
        lmask[i] = 1.0;
    }
    Ok(DistillBatch { tbatches, tokens, lmask })
}

/// Consume one [`DistillBatch`]: fill the deferred embedding rows
/// from the *current* tables, run the teacher over its blocks, pad
/// the target matrix, and take one student MSE step.  Returns the
/// step loss.  Runs on the consuming thread only (single PJRT
/// session contract + the deferred-lemb determinism contract).
pub fn distill_student_step(
    rt: &Runtime,
    ds: &GsDataset,
    tsess: &InferSession,
    st: &mut TrainState,
    db: DistillBatch,
    dims: &DistillDims,
    lr: f32,
) -> Result<f32> {
    let (b, s, h) = (dims.b, dims.s, dims.h);
    let DistillBatch { tbatches, tokens, lmask } = db;
    let mut teacher_pad = vec![0.0f32; b * h];
    let mut off = 0usize;
    for (mut tb, touch, real) in tbatches {
        fill_lemb(ds, &mut tb, &touch, 0)?;
        let res = tsess.infer(rt, &tb)?;
        let emb = res[0].as_f32()?;
        teacher_pad[off * h..(off + real) * h].copy_from_slice(&emb[..real * h]);
        off += real;
    }
    let batch = vec![
        Tensor::I32 { shape: vec![b, s], data: tokens },
        Tensor::F32 { shape: vec![b, h], data: teacher_pad },
        Tensor::F32 { shape: vec![b], data: lmask },
    ];
    let out = st.step(rt, &[lr], &batch)?;
    Ok(out.loss)
}

pub struct DistillTrainer {
    pub teacher_emb_artifact: String, // e.g. rgcn_nc_emb
    pub distill_artifact: String,     // student MSE train step
    pub student_embed_artifact: String,
}

impl Default for DistillTrainer {
    fn default() -> Self {
        DistillTrainer {
            teacher_emb_artifact: "rgcn_nc_emb".into(),
            distill_artifact: "distill_train".into(),
            student_embed_artifact: "distill_embed".into(),
        }
    }
}

impl DistillTrainer {
    /// Distill: train the student to match teacher embeddings via MSE.
    /// Returns (final loss, student state).
    ///
    /// Pipelined: worker threads sample + assemble the teacher's GNN
    /// blocks and the student's token batches ahead, while this thread
    /// runs teacher inference and the student step.  The teacher
    /// session is created once for the whole run.
    pub fn distill(
        &self,
        rt: &Runtime,
        ds: &GsDataset,
        teacher_params: &[(String, Tensor)],
        opts: &TrainOptions,
    ) -> Result<(f32, TrainState)> {
        let spec = rt.manifest.get(&self.distill_artifact)?.clone();
        let nt = ds.target_ntype;
        let store = ds.tokens[nt].as_ref().expect("target ntype needs text");
        let n = store.num_rows();
        let mut st = TrainState::new(rt, &self.distill_artifact)?;

        let tsess = InferSession::new(rt, &self.teacher_emb_artifact, teacher_params)?;
        let tspec = tsess.exe.spec.clone();
        let (dims, tshape) = DistillDims::derive(&spec, &tspec)?;

        let seed = opts.seed ^ 0xd157;
        let mut rng = Rng::seed_from(seed);
        let mut last = 0.0f32;
        // Per-worker factories pinned across epochs.
        let mut fpool = Vec::new();
        for epoch in 0..opts.epochs {
            // Distillation subsample per epoch.
            let chunks = IdChunks::new(
                (0..n as u32).collect(),
                dims.b,
                Some(DISTILL_EPOCH_SUBSAMPLE),
                &mut rng,
            );
            let mut loss_sum = 0.0;
            let mut steps = 0;
            run_pipeline_pooled(
                &chunks.chunks(),
                &opts.prefetch_cfg(),
                &mut fpool,
                || BatchFactory::new(ds, &tshape),
                |f, bi, chunk| {
                    let mut rng = Rng::seed_from(batch_seed(seed, epoch as u64, bi as u64));
                    build_distill_batch(f, store, nt, chunk, &mut rng, &tshape, &tspec, &dims)
                },
                |_, db| {
                    loss_sum +=
                        distill_student_step(rt, ds, &tsess, &mut st, db, &dims, opts.lr)?;
                    steps += 1;
                    Ok(())
                },
            )?;
            last = loss_sum / steps.max(1) as f32;
            if opts.verbose {
                crate::gs_info!("distill", "epoch {epoch}: mse {last:.5}");
            }
        }
        crate::obs::metrics::gauge_set("trainer.distill.mse", last as f64);
        Ok((last, st))
    }

    /// Student embeddings for node ids via its embed artifact.
    pub fn student_embeddings(
        &self,
        rt: &Runtime,
        ds: &GsDataset,
        artifact: &str,
        student_params: &[(String, Tensor)],
        ids: &[u32],
    ) -> Result<(Vec<f32>, usize)> {
        let sess = InferSession::new(rt, artifact, student_params)?;
        let spec = sess.exe.spec.clone();
        let b = spec.batch_spec("tokens").unwrap().shape[0];
        let s = spec.batch_spec("tokens").unwrap().shape[1];
        let h = spec.outputs[0].shape[1];
        let store = ds.tokens[ds.target_ntype].as_ref().unwrap();
        let mut out = vec![0.0f32; ids.len() * h];
        for (ci, chunk) in ids.chunks(b).enumerate() {
            let mut tokens = vec![0i32; b * s];
            for (i, &id) in chunk.iter().enumerate() {
                tokens[i * s..(i + 1) * s].copy_from_slice(store.row(id));
            }
            let res = sess.infer(rt, &[Tensor::I32 { shape: vec![b, s], data: tokens }])?;
            let emb = res[0].as_f32()?;
            for i in 0..chunk.len() {
                let dst = (ci * b + i) * h;
                out[dst..dst + h].copy_from_slice(&emb[i * h..(i + 1) * h]);
            }
        }
        Ok((out, h))
    }

    /// Paper Table-5 evaluation: train an MLP probe on embeddings of the
    /// train split, report probe accuracy on the test split.
    pub fn probe_accuracy(
        &self,
        rt: &Runtime,
        ds: &GsDataset,
        emb: &[f32],
        h: usize,
        ids: &[u32],
        opts: &TrainOptions,
    ) -> Result<f64> {
        let labels_store = ds.node_labels();
        let spec = rt.manifest.get("mlp_train")?.clone();
        let b = spec.batch_spec("emb").unwrap().shape[0];
        let hd = spec.batch_spec("emb").unwrap().shape[1];
        assert!(h <= hd);
        let mut st = TrainState::new(rt, "mlp_train")?;
        let id_index: FxHashMap<u32, usize> =
            ids.iter().enumerate().map(|(i, &x)| (x, i)).collect();
        let mut rng = Rng::seed_from(opts.seed ^ 0x9206e);
        let train: Vec<u32> = ids
            .iter()
            .copied()
            .filter(|&i| labels_store.split[i as usize] == Split::Train)
            .collect();
        let test: Vec<u32> = ids
            .iter()
            .copied()
            .filter(|&i| labels_store.split[i as usize] == Split::Test)
            .collect();
        let fill = |chunk: &[u32]| {
            let mut e = vec![0.0f32; b * hd];
            let mut labels = vec![0i32; b];
            let mut lmask = vec![0.0f32; b];
            for (i, &id) in chunk.iter().enumerate() {
                let row = id_index[&id];
                e[i * hd..i * hd + h].copy_from_slice(&emb[row * h..(row + 1) * h]);
                labels[i] = labels_store.labels[id as usize];
                lmask[i] = 1.0;
            }
            (e, labels, lmask)
        };
        for _epoch in 0..opts.epochs.max(20) {
            let mut tids = train.clone();
            rng.shuffle(&mut tids);
            for chunk in tids.chunks(b) {
                let (e, labels, lmask) = fill(chunk);
                let batch = vec![
                    Tensor::F32 { shape: vec![b, hd], data: e },
                    Tensor::I32 { shape: vec![b], data: labels },
                    Tensor::F32 { shape: vec![b], data: lmask },
                ];
                st.step(rt, &[1e-2], &batch)?;
            }
        }
        // Probe accuracy on test ids.
        let params = st.params_host()?;
        let sess = InferSession::new(rt, "mlp_logits", &params)?;
        let c = sess.exe.spec.outputs[0].shape[1];
        let mut correct = 0;
        let mut total = 0;
        for chunk in test.chunks(b) {
            let (e, labels, _lmask) = fill(chunk);
            let out = sess.infer(rt, &[Tensor::F32 { shape: vec![b, hd], data: e }])?;
            let logits = out[0].as_f32()?;
            let (cc, tt) = crate::eval::accuracy(
                &logits[..chunk.len() * c],
                c,
                &labels[..chunk.len()],
                &vec![1.0; chunk.len()],
            );
            correct += cc;
            total += tt;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }
}
