//! Link-prediction trainer (paper §3.3.4 + Appendix A).
//!
//! Supports both losses via the artifact's `loss_sel` scalar
//! (1 = contrastive, 0 = cross entropy) and all four negative
//! samplers.  Evaluation computes MRR against K sampled negatives from
//! GNN embeddings + the DistMult relation table — scoring happens in
//! Rust, embeddings come from the `*_lp_emb` infer artifact.

use anyhow::Result;

use crate::dataloader::{
    batch_seed, build_lp_batch, run_pipeline, run_pipeline_pooled, BatchFactory, GsDataset,
    IdChunks, LinkPredictionDataLoader, Split,
};
use crate::eval::{distmult, reciprocal_rank, Mean};
use crate::runtime::{Runtime, TrainState};
use crate::sampling::{EdgeExclusion, NegSampler};
use crate::serve::InferenceEngine;
use crate::trainer::encoder::EncoderStep;
use crate::trainer::TrainOptions;
use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LpLoss {
    Contrastive,
    CrossEntropy,
}

impl LpLoss {
    pub fn sel(&self) -> f32 {
        match self {
            LpLoss::Contrastive => 1.0,
            LpLoss::CrossEntropy => 0.0,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            LpLoss::Contrastive => "contrastive",
            LpLoss::CrossEntropy => "cross-entropy",
        }
    }
}

/// Manifest name of the LP embedding (eval) artifact.  The LP
/// artifacts are compiled for the rgcn trunk only.
pub const LP_EMB_ARTIFACT: &str = "rgcn_lp_emb";

/// Manifest name of the LP train artifact for a negative sampler —
/// the single place the naming scheme lives (the pipeline's single
/// `task` stage and the multi-task trainer both resolve through it).
pub fn lp_train_artifact(sampler: NegSampler) -> String {
    match sampler {
        NegSampler::Uniform { k } => format!("rgcn_lp_uniform_k{k}_train"),
        s => format!("rgcn_lp_joint_k{}_train", s.k()),
    }
}

#[derive(Debug, Clone, Default)]
pub struct LpReport {
    pub epoch_losses: Vec<f32>,
    pub epoch_times: Vec<f64>,
    pub epoch_val_mrr: Vec<f64>,
    pub val_mrr: f64,
    pub test_mrr: f64,
    /// Epochs until best val MRR (the paper's #epochs column).
    pub best_epoch: usize,
    pub steps: usize,
}

pub struct LpTrainer {
    pub train_artifact: String,
    pub emb_artifact: String,
    pub loss: LpLoss,
    pub sampler: NegSampler,
    /// Cap on train edges per epoch (scaled-down epochs).
    pub max_train_edges: Option<usize>,
    pub eval_every_epoch: bool,
}

impl LpTrainer {
    pub fn new(
        train_artifact: &str,
        emb_artifact: &str,
        loss: LpLoss,
        sampler: NegSampler,
    ) -> LpTrainer {
        LpTrainer {
            train_artifact: train_artifact.to_string(),
            emb_artifact: emb_artifact.to_string(),
            loss,
            sampler,
            max_train_edges: None,
            eval_every_epoch: true,
        }
    }

    pub fn fit(
        &self,
        rt: &Runtime,
        ds: &mut GsDataset,
        opts: &TrainOptions,
    ) -> Result<(LpReport, TrainState)> {
        let ds: &GsDataset = ds; // embedding updates go through interior mutability
        let spec = rt.manifest.get(&self.train_artifact)?.clone();
        let mut st = TrainState::new(rt, &self.train_artifact)?;
        let enc = EncoderStep::from_spec(&spec);
        let seed = opts.seed ^ 0x1b9;
        let mut rng = Rng::seed_from(seed);
        let mut report = LpReport::default();
        let mut best = (0usize, 0.0f64);

        // One loader for the whole run: its val/test edge exclusion is
        // built and sorted once, then shared by every batch.
        let loader = LinkPredictionDataLoader::new(&spec, self.sampler)?;
        let b = loader.batch_size();
        let pf = opts.prefetch_cfg();
        let all_train = ds.lp.as_ref().expect("no LP task").edge_ids_in(Split::Train);
        // Per-worker factories pinned across epochs.
        let mut fpool = Vec::new();
        for epoch in 0..opts.epochs {
            let t0 = std::time::Instant::now(); // lint:allow(determinism): epoch wall-time for the report only
            let _sp = crate::span!("trainer.lp.epoch", epoch = epoch);
            let chunks = IdChunks::new(all_train.clone(), b, self.max_train_edges, &mut rng);
            let mut epoch_loss = 0.0f32;
            let mut steps = 0usize;
            run_pipeline_pooled(
                &chunks.chunks(),
                &pf,
                &mut fpool,
                || BatchFactory::new(ds, &loader.shape),
                |f, bi, chunk| {
                    let mut rng = Rng::seed_from(batch_seed(seed, epoch as u64, bi as u64));
                    let worker = (bi % opts.n_workers.max(1)) as u32;
                    build_lp_batch(f, &loader, chunk, &mut rng, worker, true)
                },
                |bi, (mut batch, touch)| {
                    let worker = (bi % opts.n_workers.max(1)) as u32;
                    let out = enc.step(
                        rt,
                        ds,
                        &mut st,
                        &[opts.lr, self.loss.sel()],
                        &mut batch,
                        &touch,
                        worker,
                    )?;
                    epoch_loss += out.loss;
                    steps += 1;
                    Ok(())
                },
            )?;
            report.epoch_losses.push(epoch_loss / steps.max(1) as f32);
            report.epoch_times.push(t0.elapsed().as_secs_f64());
            report.steps += steps;
            crate::obs::metrics::gauge_set(
                "trainer.lp.epoch_loss",
                *report.epoch_losses.last().unwrap() as f64,
            );
            if self.eval_every_epoch {
                let mrr = self.evaluate(rt, ds, &st, Split::Val, opts)?;
                report.epoch_val_mrr.push(mrr);
                if mrr > best.1 {
                    best = (epoch + 1, mrr);
                }
                if opts.verbose {
                    crate::gs_info!(
                        &format!("lp {} {}", self.loss.label(), self.sampler.label()),
                        "epoch {epoch}: loss {:.4} val mrr {:.4} ({:.2}s)",
                        report.epoch_losses.last().unwrap(),
                        mrr,
                        report.epoch_times.last().unwrap()
                    );
                }
            }
        }
        report.val_mrr = if self.eval_every_epoch {
            best.1
        } else {
            self.evaluate(rt, ds, &st, Split::Val, opts)?
        };
        report.best_epoch = best.0.max(1);
        report.test_mrr = self.evaluate(rt, ds, &st, Split::Test, opts)?;
        Ok((report, st))
    }

    /// MRR over a split: embed (src, dst, K joint negatives) with the
    /// emb artifact, score with DistMult in Rust.  Block construction
    /// is pipelined; inference runs through the shared forward path
    /// (`serve::InferenceEngine`) + scoring stays on this thread.
    /// Seed dedup and slot lookup go through the factory's reusable
    /// Fx seed index — O(1) per seed instead of the old
    /// `Vec::contains` / `position()` scans.
    pub fn evaluate(
        &self,
        rt: &Runtime,
        ds: &GsDataset,
        st: &TrainState,
        split: Split,
        opts: &TrainOptions,
    ) -> Result<f64> {
        let params = st.params_host()?;
        let engine = InferenceEngine::from_trained(rt, ds, &self.emb_artifact, &params, opts.seed)?;
        let spec = engine.spec.clone();
        let shape = engine.shape.clone();
        let lp = ds.lp.as_ref().unwrap();
        let def = &ds.graph.schema.etypes[lp.etype];
        let es = &ds.graph.edges[lp.etype];
        let n_dst = ds.graph.num_nodes[def.dst_ntype];
        let k = 32usize;
        let b = (shape.num_targets() - k) / 2; // eval batch of positives
        let mut ids = lp.edge_ids_in(split);
        let seed = opts.seed ^ 0xe7a1;
        let mut rng = Rng::seed_from(seed);
        rng.shuffle(&mut ids);
        ids.truncate(256); // eval subsample, fixed for comparability
        let chunks: Vec<&[u32]> = ids.chunks(b).collect();
        let h = spec.outputs[0].shape[1];
        let mut mrr = Mean::default();

        run_pipeline(
            &chunks,
            &opts.prefetch_cfg(),
            || BatchFactory::new(ds, &shape),
            |f, bi, chunk| {
                let mut rng = Rng::seed_from(batch_seed(seed, 1, bi as u64));
                // Seeds: [srcs, dsts, negs(joint k)] — first-seen dedup
                // through the reusable Fx seed index, which doubles as
                // the slot map (the block preserves insertion order).
                let mut si = std::mem::take(&mut f.seed_index);
                si.begin(2 * chunk.len() + k);
                let mut seeds: Vec<(u32, u32)> = vec![];
                let mut slots: Vec<usize> = Vec::with_capacity(2 * chunk.len() + k);
                {
                    let mut push = |p: (u32, u32), seeds: &mut Vec<(u32, u32)>| {
                        let (slot, fresh) = si.get_or_insert(p.0, p.1, seeds.len());
                        if fresh {
                            seeds.push(p);
                        }
                        slots.push(slot);
                    };
                    for &eid in chunk.iter() {
                        push((def.src_ntype as u32, es.src[eid as usize]), &mut seeds);
                    }
                    for &eid in chunk.iter() {
                        push((def.dst_ntype as u32, es.dst[eid as usize]), &mut seeds);
                    }
                    for _ in 0..k {
                        let nid = rng.gen_range(n_dst) as u32;
                        push((def.dst_ntype as u32, nid), &mut seeds);
                    }
                }
                let out = f.sample_assemble(
                    &seeds,
                    &shape,
                    &spec,
                    &mut rng,
                    0,
                    &EdgeExclusion::new(),
                    false,
                );
                f.seed_index = si;
                let (batch, _) = out?;
                Ok((batch, slots, chunk.len()))
            },
            |_bi, (batch, slots, nb)| {
                let out = engine.infer_raw(&batch)?;
                let emb = out[0].as_f32()?;
                let rel = out[1].as_f32()?;
                let r = &rel[lp.etype * h..(lp.etype + 1) * h];
                let row = |s: usize| &emb[s * h..(s + 1) * h];
                for i in 0..nb {
                    let eu = row(slots[i]);
                    let ev = row(slots[nb + i]);
                    let pos = distmult(eu, r, ev);
                    let neg_scores: Vec<f32> = slots[2 * nb..]
                        .iter()
                        .map(|&s| distmult(eu, r, row(s)))
                        .collect();
                    mrr.add(reciprocal_rank(pos, &neg_scores));
                }
                Ok(())
            },
        )?;
        Ok(mrr.get())
    }
}
