//! Language-model stages (paper §3.3.1, Table 2, Figure 5).
//!
//! * `pretrain_mlm` — masked-token "pre-training" on the node corpus
//!   (the stand-in for off-the-shelf BERT weights);
//! * `finetune_nc` — task fine-tuning on node labels;
//! * `finetune_lp` — graph-aware fine-tuning with contrastive LP over
//!   the LP target edges (the paper's FTLP);
//! * `embed_all` — run the (fine-tuned) encoder over every text node
//!   and install the embeddings into the engine's text store — the
//!   "compute BERT embeddings" stage whose wall-clock Table 2 reports.
//!
//! All stages build token batches through the prefetch pipeline so
//! batch construction overlaps the PJRT step; per-batch RNG derives
//! from (seed, epoch, batch idx) for worker-count-independent output.

use anyhow::{bail, Result};

use crate::dataloader::{batch_seed, run_pipeline, GsDataset, Split};
use crate::dist::DistTensor;
use crate::runtime::{InferSession, Runtime, Tensor, TrainState};
use crate::trainer::TrainOptions;
use crate::util::Rng;

pub struct LmTrainer {
    pub mlm_artifact: String,
    pub nc_artifact: String,
    pub lp_artifact: String,
    pub embed_artifact: String,
}

impl Default for LmTrainer {
    fn default() -> Self {
        LmTrainer {
            mlm_artifact: "lm_mlm_train".into(),
            nc_artifact: "lm_nc_train".into(),
            lp_artifact: "lm_lp_train".into(),
            embed_artifact: "lm_embed".into(),
        }
    }
}

/// Collect token rows for node ids, padding the batch by repetition.
fn token_batch(ds: &GsDataset, ntype: usize, ids: &[u32], b: usize, s: usize) -> Vec<i32> {
    let store = ds.tokens[ntype].as_ref().expect("ntype has no tokens");
    let mut out = vec![0i32; b * s];
    for i in 0..b {
        let id = ids[i.min(ids.len() - 1)];
        out[i * s..(i + 1) * s].copy_from_slice(store.row(id));
    }
    out
}

impl LmTrainer {
    /// Masked-token pretraining over all text nodes of `ntype`.
    /// Returns (mean last-epoch loss, trained state).
    pub fn pretrain_mlm(
        &self,
        rt: &Runtime,
        ds: &GsDataset,
        ntype: usize,
        opts: &TrainOptions,
    ) -> Result<(f32, TrainState)> {
        let spec = rt.manifest.get(&self.mlm_artifact)?.clone();
        let b = spec.batch_spec("tokens").unwrap().shape[0];
        let s = spec.batch_spec("tokens").unwrap().shape[1];
        let mut st = TrainState::new(rt, &self.mlm_artifact)?;
        let n = ds.tokens[ntype].as_ref().unwrap().num_rows();
        let seed = opts.seed ^ 0x1717;
        let mut rng = Rng::seed_from(seed);
        let mut last = 0.0;
        for epoch in 0..opts.epochs {
            let mut ids: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut ids);
            let chunks: Vec<&[u32]> = ids.chunks(b).collect();
            let mut loss_sum = 0.0f32;
            let mut steps = 0;
            run_pipeline(
                &chunks,
                &opts.prefetch_cfg(),
                || (),
                |_, bi, chunk| {
                    let mut rng = Rng::seed_from(batch_seed(seed, epoch as u64, bi as u64));
                    let mut tokens = token_batch(ds, ntype, chunk, b, s);
                    let mut positions = vec![0i32; b];
                    let mut labels = vec![0i32; b];
                    let mut lmask = vec![0.0f32; b];
                    for i in 0..chunk.len() {
                        // Mask one random non-pad position.
                        let p = rng.gen_range(s);
                        positions[i] = p as i32;
                        labels[i] = tokens[i * s + p];
                        tokens[i * s + p] = 1; // [MASK]
                        lmask[i] = 1.0;
                    }
                    Ok(vec![
                        Tensor::I32 { shape: vec![b, s], data: tokens },
                        Tensor::I32 { shape: vec![b], data: positions },
                        Tensor::I32 { shape: vec![b], data: labels },
                        Tensor::F32 { shape: vec![b], data: lmask },
                    ])
                },
                |_, batch| {
                    let out = st.step(rt, &[opts.lr], &batch)?;
                    loss_sum += out.loss;
                    steps += 1;
                    Ok(())
                },
            )?;
            last = loss_sum / steps.max(1) as f32;
            if opts.verbose {
                crate::gs_info!("lm mlm", "epoch {epoch}: loss {last:.4}");
            }
        }
        crate::obs::metrics::gauge_set("trainer.lm.mlm_loss", last as f64);
        Ok((last, st))
    }

    /// Fine-tune with node-classification labels (FTNC).  `base` params
    /// (e.g. from pretraining) seed the encoder.
    pub fn finetune_nc(
        &self,
        rt: &Runtime,
        ds: &GsDataset,
        base: &[(String, Tensor)],
        opts: &TrainOptions,
    ) -> Result<(f32, TrainState)> {
        let spec = rt.manifest.get(&self.nc_artifact)?.clone();
        let b = spec.batch_spec("tokens").unwrap().shape[0];
        let s = spec.batch_spec("tokens").unwrap().shape[1];
        let nt = ds.target_ntype;
        if ds.tokens[nt].is_none() {
            bail!("target ntype has no text");
        }
        let mut st = TrainState::with_params(rt, &self.nc_artifact, base)?;
        let labels_store = ds.node_labels();
        let train_ids = labels_store.ids_in(Split::Train);
        let mut rng = Rng::seed_from(opts.seed ^ 0xf17c);
        let mut last = 0.0;
        for epoch in 0..opts.epochs {
            let mut ids = train_ids.clone();
            rng.shuffle(&mut ids);
            let chunks: Vec<&[u32]> = ids.chunks(b).collect();
            let mut loss_sum = 0.0f32;
            let mut steps = 0;
            run_pipeline(
                &chunks,
                &opts.prefetch_cfg(),
                || (),
                |_, _bi, chunk| {
                    let tokens = token_batch(ds, nt, chunk, b, s);
                    let mut labels = vec![0i32; b];
                    let mut lmask = vec![0.0f32; b];
                    for (i, &id) in chunk.iter().enumerate() {
                        labels[i] = labels_store.labels[id as usize];
                        lmask[i] = 1.0;
                    }
                    Ok(vec![
                        Tensor::I32 { shape: vec![b, s], data: tokens },
                        Tensor::I32 { shape: vec![b], data: labels },
                        Tensor::F32 { shape: vec![b], data: lmask },
                    ])
                },
                |_, batch| {
                    let out = st.step(rt, &[opts.lr], &batch)?;
                    loss_sum += out.loss;
                    steps += 1;
                    Ok(())
                },
            )?;
            last = loss_sum / steps.max(1) as f32;
            if opts.verbose {
                crate::gs_info!("lm ftnc", "epoch {epoch}: loss {last:.4}");
            }
        }
        crate::obs::metrics::gauge_set("trainer.lm.ftnc_loss", last as f64);
        Ok((last, st))
    }

    /// Graph-aware fine-tuning with contrastive link prediction (FTLP)
    /// over the dataset's LP edges (both endpoints must carry text).
    pub fn finetune_lp(
        &self,
        rt: &Runtime,
        ds: &GsDataset,
        base: &[(String, Tensor)],
        opts: &TrainOptions,
    ) -> Result<(f32, TrainState)> {
        let spec = rt.manifest.get(&self.lp_artifact)?.clone();
        let b = spec.batch_spec("src_tokens").unwrap().shape[0];
        let s = spec.batch_spec("src_tokens").unwrap().shape[1];
        let k = spec.batch_spec("neg_tokens").unwrap().shape[0];
        let lp = ds.lp.as_ref().expect("no LP task");
        let def = &ds.graph.schema.etypes[lp.etype];
        let es = &ds.graph.edges[lp.etype];
        if ds.tokens[def.src_ntype].is_none() || ds.tokens[def.dst_ntype].is_none() {
            bail!("LP endpoints lack text for FTLP");
        }
        let n_dst = ds.graph.num_nodes[def.dst_ntype];
        let mut st = TrainState::with_params(rt, &self.lp_artifact, base)?;
        let train_ids = lp.edge_ids_in(Split::Train);
        let seed = opts.seed ^ 0xf17b;
        let mut rng = Rng::seed_from(seed);
        let mut last = 0.0;
        for epoch in 0..opts.epochs {
            let mut ids = train_ids.clone();
            rng.shuffle(&mut ids);
            ids.truncate(4096); // scaled-down FTLP epoch
            let chunks: Vec<&[u32]> = ids.chunks(b).collect();
            let mut loss_sum = 0.0f32;
            let mut steps = 0;
            run_pipeline(
                &chunks,
                &opts.prefetch_cfg(),
                || (),
                |_, bi, chunk| {
                    let mut rng = Rng::seed_from(batch_seed(seed, epoch as u64, bi as u64));
                    let srcs: Vec<u32> = chunk.iter().map(|&e| es.src[e as usize]).collect();
                    let dsts: Vec<u32> = chunk.iter().map(|&e| es.dst[e as usize]).collect();
                    let negs: Vec<u32> = (0..k).map(|_| rng.gen_range(n_dst) as u32).collect();
                    let mut pmask = vec![0.0f32; b];
                    for i in 0..chunk.len() {
                        pmask[i] = 1.0;
                    }
                    Ok(vec![
                        Tensor::I32 {
                            shape: vec![b, s],
                            data: token_batch(ds, def.src_ntype, &srcs, b, s),
                        },
                        Tensor::I32 {
                            shape: vec![b, s],
                            data: token_batch(ds, def.dst_ntype, &dsts, b, s),
                        },
                        Tensor::I32 {
                            shape: vec![k, s],
                            data: token_batch(ds, def.dst_ntype, &negs, k, s),
                        },
                        Tensor::F32 { shape: vec![b], data: pmask },
                    ])
                },
                |_, batch| {
                    let out = st.step(rt, &[opts.lr], &batch)?;
                    loss_sum += out.loss;
                    steps += 1;
                    Ok(())
                },
            )?;
            last = loss_sum / steps.max(1) as f32;
            if opts.verbose {
                crate::gs_info!("lm ftlp", "epoch {epoch}: loss {last:.4}");
            }
        }
        crate::obs::metrics::gauge_set("trainer.lm.ftlp_loss", last as f64);
        Ok((last, st))
    }

    /// Compute LM embeddings for every text node of each ntype and
    /// install them into `engine.text_emb` (the Table-2 "LM Time Cost"
    /// stage).  Returns elapsed seconds.
    pub fn embed_all(
        &self,
        rt: &Runtime,
        ds: &mut GsDataset,
        lm_params: &[(String, Tensor)],
        opts: &TrainOptions,
    ) -> Result<f64> {
        let t0 = std::time::Instant::now(); // lint:allow(determinism): epoch wall-time for the report only
        let sess = InferSession::new(rt, &self.embed_artifact, lm_params)?;
        let spec = sess.exe.spec.clone();
        let b = spec.batch_spec("tokens").unwrap().shape[0];
        let s = spec.batch_spec("tokens").unwrap().shape[1];
        let h = spec.outputs[0].shape[1];
        let cfg = opts.prefetch_cfg();
        for nt in 0..ds.graph.schema.ntypes.len() {
            if ds.tokens[nt].is_none() {
                continue;
            }
            let n = ds.tokens[nt].as_ref().unwrap().num_rows();
            let mut emb = vec![0.0f32; n * h];
            let ids: Vec<u32> = (0..n as u32).collect();
            let chunks: Vec<&[u32]> = ids.chunks(b).collect();
            {
                let dsr: &GsDataset = ds;
                run_pipeline(
                    &chunks,
                    &cfg,
                    || (),
                    |_, _bi, chunk| Ok((token_batch(dsr, nt, chunk, b, s), chunk.to_vec())),
                    |_, (tokens, chunk)| {
                        let out =
                            sess.infer(rt, &[Tensor::I32 { shape: vec![b, s], data: tokens }])?;
                        let rows = out[0].as_f32()?;
                        for (i, &id) in chunk.iter().enumerate() {
                            emb[id as usize * h..(id as usize + 1) * h]
                                .copy_from_slice(&rows[i * h..(i + 1) * h]);
                        }
                        Ok(())
                    },
                )?;
            }
            ds.engine.text_emb[nt] = DistTensor::from_data(
                nt,
                h,
                emb,
                ds.engine.book.clone(),
                ds.engine.counters.clone(),
            );
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Accuracy of "LM alone" on the NC task via `lm_nc_logits`.
    pub fn evaluate_nc(
        &self,
        rt: &Runtime,
        ds: &GsDataset,
        st: &TrainState,
        split: Split,
    ) -> Result<f64> {
        let params = st.params_host()?;
        let sess = InferSession::new(rt, "lm_nc_logits", &params)?;
        let spec = sess.exe.spec.clone();
        let b = spec.batch_spec("tokens").unwrap().shape[0];
        let s = spec.batch_spec("tokens").unwrap().shape[1];
        let c = spec.outputs[0].shape[1];
        let nt = ds.target_ntype;
        let labels_store = ds.node_labels();
        let ids = labels_store.ids_in(split);
        let mut correct = 0usize;
        let mut total = 0usize;
        for chunk in ids.chunks(b) {
            let tokens = token_batch(ds, nt, chunk, b, s);
            let out = sess.infer(rt, &[Tensor::I32 { shape: vec![b, s], data: tokens }])?;
            let logits = out[0].as_f32()?;
            let (cc, tt) = crate::eval::accuracy(
                &logits[..chunk.len() * c],
                c,
                &chunk.iter().map(|&i| labels_store.labels[i as usize]).collect::<Vec<_>>(),
                &vec![1.0; chunk.len()],
            );
            correct += cc;
            total += tt;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }
}
