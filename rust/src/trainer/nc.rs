//! Node-classification trainer + evaluator, pipelined: worker threads
//! sample + assemble batches ahead while this thread runs the PJRT
//! step (learnable-embedding rows are deferred to the step thread, so
//! results are bit-identical for any `loader_workers`).

use anyhow::Result;

use crate::dataloader::{
    batch_seed, run_pipeline, BatchFactory, GsDataset, IdChunks, NodeDataLoader,
    PrefetchingLoader, Split,
};
use crate::runtime::{Runtime, TrainState};
use crate::sampling::EdgeExclusion;
use crate::serve::InferenceEngine;
use crate::trainer::encoder::EncoderStep;
use crate::trainer::TrainOptions;
use crate::util::Rng;

#[derive(Debug, Clone, Default)]
pub struct NcReport {
    pub epoch_losses: Vec<f32>,
    pub epoch_times: Vec<f64>,
    pub val_acc: f64,
    pub test_acc: f64,
    pub steps: usize,
}

pub struct NodeTrainer {
    pub train_artifact: String,
    pub infer_artifact: String,
}

impl NodeTrainer {
    pub fn new(train_artifact: &str, infer_artifact: &str) -> NodeTrainer {
        NodeTrainer {
            train_artifact: train_artifact.to_string(),
            infer_artifact: infer_artifact.to_string(),
        }
    }

    /// Train; returns the report and the trained state.
    pub fn fit(
        &self,
        rt: &Runtime,
        ds: &mut GsDataset,
        opts: &TrainOptions,
    ) -> Result<(NcReport, TrainState)> {
        let ds: &GsDataset = ds; // embedding updates go through interior mutability
        let spec = rt.manifest.get(&self.train_artifact)?.clone();
        let mut st = TrainState::new(rt, &self.train_artifact)?;
        let loader = NodeDataLoader::new(&spec)?;
        let b = loader.batch_size();
        let enc = EncoderStep::from_spec(&spec);
        let seed = opts.seed ^ 0x6e63; // "nc"
        let mut rng = Rng::seed_from(seed);
        let train_ids = ds.node_labels().ids_in(Split::Train);
        let mut report = NcReport::default();
        // Holds the pinned per-worker factories across epochs.
        let mut pfl = PrefetchingLoader::new(&loader, ds, opts.prefetch_cfg());

        for epoch in 0..opts.epochs {
            let t0 = std::time::Instant::now(); // lint:allow(determinism): epoch wall-time for the report only
            let _sp = crate::span!("trainer.nc.epoch", epoch = epoch);
            let chunks = IdChunks::new(train_ids.clone(), b, None, &mut rng);
            let mut epoch_loss = 0.0f32;
            let mut steps = 0usize;
            pfl.for_each(
                &chunks.chunks(),
                seed,
                epoch as u64,
                opts.n_workers,
                |bi, (mut batch, touch)| {
                    let worker = (bi % opts.n_workers.max(1)) as u32;
                    let out =
                        enc.step(rt, ds, &mut st, &[opts.lr], &mut batch, &touch, worker)?;
                    epoch_loss += out.loss;
                    steps += 1;
                    if opts.log_every > 0 && bi % opts.log_every == 0 && opts.verbose {
                        crate::gs_info!("nc", "epoch {epoch} step {bi} loss {:.4}", out.loss);
                    }
                    Ok(())
                },
            )?;
            report.epoch_losses.push(epoch_loss / steps.max(1) as f32);
            report.epoch_times.push(t0.elapsed().as_secs_f64());
            report.steps += steps;
            crate::obs::metrics::gauge_set(
                "trainer.nc.epoch_loss",
                *report.epoch_losses.last().unwrap() as f64,
            );
            if opts.verbose {
                crate::gs_info!(
                    "nc",
                    "epoch {epoch}: mean loss {:.4} ({:.2}s)",
                    report.epoch_losses.last().unwrap(),
                    report.epoch_times.last().unwrap()
                );
            }
        }
        report.val_acc = self.evaluate(rt, ds, &st, Split::Val, opts)?;
        report.test_acc = self.evaluate(rt, ds, &st, Split::Test, opts)?;
        Ok((report, st))
    }

    /// Accuracy over a split via the logits infer artifact; block
    /// construction is pipelined, inference runs on this thread
    /// through the shared forward path (`serve::InferenceEngine`).
    pub fn evaluate(
        &self,
        rt: &Runtime,
        ds: &GsDataset,
        st: &TrainState,
        split: Split,
        opts: &TrainOptions,
    ) -> Result<f64> {
        let params = st.params_host()?;
        let engine = InferenceEngine::from_trained(rt, ds, &self.infer_artifact, &params, opts.seed)?;
        let spec = engine.spec.clone();
        let shape = engine.shape.clone();
        let b = spec.cfg_usize("batch").unwrap_or(shape.num_targets());
        let c = *spec.outputs[0].shape.last().unwrap();
        let ids = ds.node_labels().ids_in(split);
        let seed = opts.seed ^ 0xe7a1;
        let chunks: Vec<&[u32]> = ids.chunks(b).collect();
        let labels_store = ds.node_labels();
        let mut correct = 0usize;
        let mut total = 0usize;
        run_pipeline(
            &chunks,
            &opts.prefetch_cfg(),
            || BatchFactory::new(ds, &shape),
            |f, bi, chunk| {
                let mut rng = Rng::seed_from(batch_seed(seed, 0, bi as u64));
                let seeds: Vec<(u32, u32)> =
                    chunk.iter().map(|&i| (ds.target_ntype as u32, i)).collect();
                let (batch, _) = f.sample_assemble(
                    &seeds,
                    &shape,
                    &spec,
                    &mut rng,
                    0,
                    &EdgeExclusion::new(),
                    false,
                )?;
                Ok((batch, f.targets().to_vec()))
            },
            |_bi, (batch, targets)| {
                let out = engine.infer_raw(&batch)?;
                let logits = out[0].as_f32()?;
                for (i, &(_, id)) in targets.iter().enumerate() {
                    let am = crate::eval::argmax(&logits[i * c..(i + 1) * c]);
                    if am as i32 == labels_store.labels[id as usize] {
                        correct += 1;
                    }
                    total += 1;
                }
                Ok(())
            },
        )?;
        Ok(correct as f64 / total.max(1) as f64)
    }
}
