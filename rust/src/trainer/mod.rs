//! Trainers: the end-to-end pipelines (paper §3.1.3).
//!
//! Each trainer drives one AOT train artifact over on-the-fly sampled
//! batches, applies embedding-table gradients, evaluates with the
//! matching infer artifact, and reports per-epoch history.  Multi-part
//! runs rotate the acting worker per batch so the traffic counters see
//! the same local/remote mix a real cluster would.
//!
//! The forward-only half (sample → assemble → execute infer artifact →
//! decode) lives in [`crate::serve::InferenceEngine`]; the evaluators
//! here run their batches through it, and the online serving layer
//! reuses the exact same path for request traffic.
//!
//! The encoder forward/backward path every training loop drives is
//! one implementation ([`encoder::EncoderStep`]); single-task
//! trainers are thin wrappers over it, and [`multi::MultiTaskTrainer`]
//! interleaves several task heads over the same shared trunk.

pub mod distill;
pub mod encoder;
pub mod lm;
pub mod lp;
pub mod multi;
pub mod nc;

pub use distill::DistillTrainer;
pub use encoder::EncoderStep;
pub use lm::LmTrainer;
pub use lp::{LpReport, LpTrainer};
pub use multi::{HeadKind, MultiReport, MultiTaskTrainer, TaskSpec};
pub use nc::{NcReport, NodeTrainer};

/// Shared training knobs.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub lr: f32,
    pub epochs: usize,
    pub seed: u64,
    /// Logical workers (= partitions) to rotate batches across.
    pub n_workers: usize,
    /// Batch-building threads for the prefetching loader
    /// (CLI `--num-workers`); 1 = serial.  Output is bit-identical for
    /// any value — per-batch RNG derives from (seed, epoch, batch idx).
    pub loader_workers: usize,
    /// Batches each loader worker builds ahead (CLI `--prefetch`).
    pub prefetch: usize,
    pub log_every: usize,
    pub verbose: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            lr: 3e-3,
            epochs: 5,
            seed: 0,
            n_workers: 1,
            loader_workers: 1,
            prefetch: 2,
            log_every: 0,
            verbose: false,
        }
    }
}

impl TrainOptions {
    /// The pipelining knobs as a loader config.
    pub fn prefetch_cfg(&self) -> crate::dataloader::PrefetchConfig {
        crate::dataloader::PrefetchConfig {
            n_workers: self.loader_workers,
            depth: self.prefetch,
        }
    }
}
