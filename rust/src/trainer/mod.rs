//! Trainers: the end-to-end pipelines (paper §3.1.3).
//!
//! Each trainer drives one AOT train artifact over on-the-fly sampled
//! batches, applies embedding-table gradients, evaluates with the
//! matching infer artifact, and reports per-epoch history.  Multi-part
//! runs rotate the acting worker per batch so the traffic counters see
//! the same local/remote mix a real cluster would.

pub mod distill;
pub mod lm;
pub mod lp;
pub mod nc;

pub use distill::DistillTrainer;
pub use lm::LmTrainer;
pub use lp::{LpReport, LpTrainer};
pub use nc::{NcReport, NodeTrainer};

/// Shared training knobs.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub lr: f32,
    pub epochs: usize,
    pub seed: u64,
    /// Logical workers (= partitions) to rotate batches across.
    pub n_workers: usize,
    pub log_every: usize,
    pub verbose: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions { lr: 3e-3, epochs: 5, seed: 0, n_workers: 1, log_every: 0, verbose: false }
    }
}
