//! Multi-task training: one shared encoder trunk, per-task heads,
//! deterministic weighted round-robin batch interleaving.
//!
//! The GraphStorm paper's core pitch is one framework covering many
//! GML workloads on one graph; this module is the combined form — a
//! single run trains node classification, link prediction and
//! GNN→LM distillation heads over **one** shared encoder trunk
//! instead of three isolated trainers each paying for the encoder
//! machinery:
//!
//! * **Shared trunk** — the sparse encoder state (learnable embedding
//!   tables + text embeddings in the dataset's `DistEngine`) is
//!   updated in place by every head through the one
//!   [`EncoderStep`](crate::trainer::encoder::EncoderStep)
//!   forward/backward path, and all heads share the sampling/assembly
//!   machinery (`BatchFactory`).  Dense head weights (GNN layers +
//!   decoders + Adam moments) remain per-head device state.
//! * **Per-task heads** — nc / lp / distill, each a thin consumer of
//!   its routed batches.  The distill head's teacher is the run's NC
//!   head, refreshed from its parameters at each epoch start (the
//!   "chained nc + distill" scenario), so distillation tracks the
//!   representation as it trains.
//! * **Deterministic schedule** — [`build_schedule`] interleaves tasks
//!   per mini-batch by a weighted draw whose RNG comes from
//!   `batch_seed(seed ^ SCHED_SALT, epoch, item)`, the repo's
//!   per-batch RNG convention.  The schedule is precomputed before
//!   the pipeline runs and every task batch derives its RNG from its
//!   *per-task* batch index, so the whole interleaved stream is
//!   bit-identical for any `--num-workers` (`rust/tests/determinism.rs`
//!   sweeps {1, 2, 4, 8}) — and each task's sub-stream is
//!   bit-identical to what the standalone trainer would build from
//!   the same seed.

use anyhow::{anyhow, bail, Result};

use crate::dataloader::{
    batch_seed, build_lp_batch, build_nc_batch, run_pipeline_pooled, BatchFactory, GsDataset,
    IdChunks, LembTouch, LinkPredictionDataLoader, NodeDataLoader, Split,
};
use crate::runtime::{ArtifactSpec, InferSession, Runtime, Tensor, TrainState};
use crate::sampling::{BlockShape, NegSampler};
use crate::trainer::distill::{
    build_distill_batch, distill_student_step, DistillBatch, DistillDims, DistillTrainer,
    DISTILL_EPOCH_SUBSAMPLE,
};
use crate::trainer::encoder::EncoderStep;
use crate::trainer::lp::{lp_train_artifact, LpLoss, LpReport, LpTrainer, LP_EMB_ARTIFACT};
use crate::trainer::nc::{NcReport, NodeTrainer};
use crate::trainer::TrainOptions;
use crate::util::Rng;

/// Per-task seed salts — identical to the standalone trainers', so a
/// task's batch sub-stream inside a multi-task run is bit-identical
/// to the stream the standalone trainer builds from the same seed.
const NC_SALT: u64 = 0x6e63;
const LP_SALT: u64 = 0x1b9;
const DISTILL_SALT: u64 = 0xd157;
/// Schedule salt: the round-robin draws must not share a stream with
/// any task's batch RNG.
const SCHED_SALT: u64 = 0x5c4ed;

/// What one head trains.
#[derive(Debug, Clone)]
pub enum HeadKind {
    Nc,
    Lp { loss: LpLoss, sampler: NegSampler, max_edges: Option<usize> },
    /// Distills the run's (required) NC head into the graph-free
    /// student LM; the teacher refreshes from the NC head's current
    /// parameters at each epoch start.
    Distill,
}

impl HeadKind {
    pub fn name(&self) -> &'static str {
        match self {
            HeadKind::Nc => "nc",
            HeadKind::Lp { .. } => "lp",
            HeadKind::Distill => "distill",
        }
    }

    fn salt(&self) -> u64 {
        match self {
            HeadKind::Nc => NC_SALT,
            HeadKind::Lp { .. } => LP_SALT,
            HeadKind::Distill => DISTILL_SALT,
        }
    }
}

/// One task in a multi-task run: a head, its schedule weight, and an
/// optional per-head learning rate (default: the shared `opts.lr`).
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub head: HeadKind,
    pub weight: f64,
    pub lr: Option<f32>,
}

impl TaskSpec {
    pub fn new(head: HeadKind) -> TaskSpec {
        TaskSpec { head, weight: 1.0, lr: None }
    }
}

/// Deterministic weighted round-robin: item `i` of an epoch picks the
/// next task by a categorical draw over `weights`, masked to tasks
/// with batches remaining, from an RNG seeded by
/// `batch_seed(seed ^ SCHED_SALT, epoch, i)`.  A pure function of
/// (seed, epoch, counts, weights) — no shared stream, so the schedule
/// is bit-identical regardless of who computes it or how many loader
/// workers later consume it.
pub fn build_schedule(seed: u64, epoch: u64, counts: &[usize], weights: &[f64]) -> Vec<usize> {
    assert_eq!(counts.len(), weights.len(), "one weight per task");
    let mut rem = counts.to_vec();
    let total: usize = rem.iter().sum();
    let mut order = Vec::with_capacity(total);
    let mut w = vec![0.0f64; rem.len()];
    for i in 0..total {
        let mut rng = Rng::seed_from(batch_seed(seed ^ SCHED_SALT, epoch, i as u64));
        for (slot, (&r, &wt)) in w.iter_mut().zip(rem.iter().zip(weights)) {
            *slot = if r > 0 { wt } else { 0.0 };
        }
        let mut t = rng.gen_categorical(&w);
        if rem[t] == 0 {
            // Float-edge fallback (a rounding tie can land on a
            // drained zero-weight tail): first task with work left.
            t = rem.iter().position(|&r| r > 0).expect("i < total, so batches remain");
        }
        order.push(t);
        rem[t] -= 1;
    }
    order
}

/// The distill head's specs: the teacher emb artifact (sampling needs
/// its spec + block shape) and the dims derived from it together with
/// the student train artifact's spec.
pub struct DistillSpecs {
    pub tspec: ArtifactSpec,
    pub tshape: BlockShape,
    pub dims: DistillDims,
}

impl DistillSpecs {
    pub fn derive(spec: &ArtifactSpec, tspec: ArtifactSpec) -> Result<DistillSpecs> {
        let (dims, tshape) = DistillDims::derive(spec, &tspec)?;
        Ok(DistillSpecs { tspec, tshape, dims })
    }
}

/// Per-head loaders/specs — from the runtime manifest in real runs
/// ([`MultiTaskTrainer::fit`] builds them), or synthesized in tests so
/// the interleaved batch stream runs without AOT artifacts.
pub struct MultiSpecs {
    pub nc: Option<NodeDataLoader>,
    pub lp: Option<LinkPredictionDataLoader>,
    pub distill: Option<DistillSpecs>,
}

/// One routed work item of the interleaved stream.
#[derive(Debug, PartialEq)]
pub enum MultiBatch {
    Nc(Vec<Tensor>, LembTouch),
    Lp(Vec<Tensor>, LembTouch),
    Distill(DistillBatch),
}

/// Per-worker batch-building state: one factory per declared head
/// (each head samples a different block shape).
struct MultiFactory<'a> {
    nc: Option<BatchFactory<'a>>,
    lp: Option<BatchFactory<'a>>,
    distill: Option<BatchFactory<'a>>,
}

impl<'a> MultiFactory<'a> {
    fn new(ds: &'a GsDataset, specs: &MultiSpecs) -> MultiFactory<'a> {
        MultiFactory {
            nc: specs.nc.as_ref().map(|l| BatchFactory::new(ds, &l.shape)),
            lp: specs.lp.as_ref().map(|l| BatchFactory::new(ds, &l.shape)),
            distill: specs.distill.as_ref().map(|d| BatchFactory::new(ds, &d.tshape)),
        }
    }
}

/// Opaque per-worker factory pool for the interleaved batch stream,
/// pinned across epochs (see `dataloader::run_pipeline_pooled`).
/// Start from `default()` and pass the same pool to every
/// [`MultiTaskTrainer::epoch_batches_pooled`] call.
#[derive(Default)]
pub struct MultiFactoryPool<'a>(Vec<Option<MultiFactory<'a>>>);

/// Per-task results of a multi-task run (the pipeline reports these
/// per task in `PipelineOutcome`).
#[derive(Debug, Clone, Default)]
pub struct MultiReport {
    /// Task names, in declaration order.
    pub names: Vec<String>,
    /// Mean train loss per epoch, per task (declaration order).
    pub epoch_losses: Vec<Vec<f32>>,
    /// Train steps run, per task.
    pub steps: Vec<usize>,
    pub nc: Option<NcReport>,
    pub lp: Option<LpReport>,
    pub distill_mse: Option<f32>,
}

/// One per-task head: its device train state plus the shared encoder
/// step (nc/lp) or the student state (distill).
enum Head {
    Nc { st: TrainState, enc: EncoderStep },
    Lp { st: TrainState, enc: EncoderStep, sel: f32 },
    Distill { st: TrainState },
}

pub struct MultiTaskTrainer {
    pub arch: String,
    pub tasks: Vec<TaskSpec>,
}

impl MultiTaskTrainer {
    pub fn new(arch: &str, tasks: Vec<TaskSpec>) -> MultiTaskTrainer {
        MultiTaskTrainer { arch: arch.to_string(), tasks }
    }

    /// Structural checks shared with the config layer: at least one
    /// task, one head per kind, positive finite weights, and distill
    /// only alongside an NC head (its teacher).
    pub fn validate(&self) -> Result<()> {
        if self.tasks.is_empty() {
            bail!("multi-task run declares no tasks");
        }
        for t in &self.tasks {
            if !(t.weight > 0.0 && t.weight.is_finite()) {
                bail!("task '{}' weight must be a positive finite number", t.head.name());
            }
        }
        for (i, t) in self.tasks.iter().enumerate() {
            if self.tasks[..i].iter().any(|o| o.head.name() == t.head.name()) {
                bail!("duplicate task kind '{}' in the tasks array", t.head.name());
            }
        }
        let has = |n: &str| self.tasks.iter().any(|t| t.head.name() == n);
        if has("distill") && !has("nc") {
            bail!("a distill task needs an nc task in the same run (its teacher)");
        }
        if self.arch != "rgcn" && has("lp") {
            // The LP train/emb artifacts are compiled for the rgcn
            // trunk only; training them beside a different-arch NC
            // head would silently break the shared-encoder claim.
            bail!(
                "multi-task lp heads are wired to the rgcn artifacts; \
                 the shared encoder arch must be \"rgcn\" when an lp task is declared \
                 (got \"{}\")",
                self.arch
            );
        }
        Ok(())
    }

    /// Position of a head kind in the tasks array.
    fn index_of(&self, name: &str) -> Option<usize> {
        self.tasks.iter().position(|t| t.head.name() == name)
    }

    /// Fresh per-task shuffle streams: seeded exactly like the
    /// standalone trainers' (`seed ^ salt`) and persistent across
    /// epochs, so epoch shuffles match single-task runs.
    pub fn shuffle_rngs(&self, seed: u64) -> Vec<Rng> {
        self.tasks.iter().map(|t| Rng::seed_from(seed ^ t.head.salt())).collect()
    }

    /// Build one epoch's interleaved batch stream and hand each item —
    /// in schedule order — to `consume(task_idx, task_batch_idx,
    /// batch)` on the calling thread.  `shuffles` comes from
    /// [`Self::shuffle_rngs`] and advances exactly like the standalone
    /// trainers' streams.  Returns the per-task batch counts of the
    /// epoch.
    ///
    /// Determinism: the schedule is precomputed, every task batch's
    /// RNG derives from `batch_seed(seed ^ task_salt, epoch,
    /// task_batch_idx)`, and learnable-embedding rows stay deferred —
    /// so the stream is bit-identical for any `opts.loader_workers`.
    pub fn epoch_batches(
        &self,
        ds: &GsDataset,
        specs: &MultiSpecs,
        opts: &TrainOptions,
        epoch: usize,
        shuffles: &mut [Rng],
        consume: impl FnMut(usize, usize, MultiBatch) -> Result<()>,
    ) -> Result<Vec<usize>> {
        let mut pool = MultiFactoryPool::default();
        self.epoch_batches_pooled(ds, specs, opts, epoch, shuffles, &mut pool, consume)
    }

    /// [`Self::epoch_batches`] with worker factories pinned across
    /// calls: multi-epoch drivers hold one [`MultiFactoryPool`] so the
    /// per-head `BatchFactory` scratch is built once, not per epoch.
    pub fn epoch_batches_pooled<'a>(
        &self,
        ds: &'a GsDataset,
        specs: &MultiSpecs,
        opts: &TrainOptions,
        epoch: usize,
        shuffles: &mut [Rng],
        pool: &mut MultiFactoryPool<'a>,
        mut consume: impl FnMut(usize, usize, MultiBatch) -> Result<()>,
    ) -> Result<Vec<usize>> {
        if shuffles.len() != self.tasks.len() {
            bail!("need one shuffle stream per task (got {})", shuffles.len());
        }
        let seed = opts.seed;
        // Per-task work lists, shuffled by the persistent streams.
        let mut chunks: Vec<IdChunks> = Vec::with_capacity(self.tasks.len());
        for (t, rng) in self.tasks.iter().zip(shuffles.iter_mut()) {
            let c = match &t.head {
                HeadKind::Nc => {
                    let loader = specs
                        .nc
                        .as_ref()
                        .ok_or_else(|| anyhow!("nc task declared but no nc specs"))?;
                    let ids = ds.node_labels().ids_in(Split::Train);
                    IdChunks::new(ids, loader.batch_size(), None, rng)
                }
                HeadKind::Lp { max_edges, .. } => {
                    let loader = specs
                        .lp
                        .as_ref()
                        .ok_or_else(|| anyhow!("lp task declared but no lp specs"))?;
                    let ids = ds
                        .lp
                        .as_ref()
                        .ok_or_else(|| anyhow!("dataset has no LP task"))?
                        .edge_ids_in(Split::Train);
                    IdChunks::new(ids, loader.batch_size(), *max_edges, rng)
                }
                HeadKind::Distill => {
                    let dsp = specs
                        .distill
                        .as_ref()
                        .ok_or_else(|| anyhow!("distill task declared but no distill specs"))?;
                    let store = ds.tokens[ds.target_ntype]
                        .as_ref()
                        .ok_or_else(|| anyhow!("target ntype needs text for distillation"))?;
                    let ids: Vec<u32> = (0..store.num_rows() as u32).collect();
                    IdChunks::new(ids, dsp.dims.b, Some(DISTILL_EPOCH_SUBSAMPLE), rng)
                }
            };
            chunks.push(c);
        }
        let counts: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
        let weights: Vec<f64> = self.tasks.iter().map(|t| t.weight).collect();
        let schedule = build_schedule(seed, epoch as u64, &counts, &weights);
        // Route each schedule slot to (task, per-task batch index).
        let mut next = vec![0usize; self.tasks.len()];
        let items: Vec<(usize, usize)> = schedule
            .iter()
            .map(|&t| {
                let bi = next[t];
                next[t] += 1;
                (t, bi)
            })
            .collect();

        let nw = opts.n_workers.max(1);
        run_pipeline_pooled(
            &items,
            &opts.prefetch_cfg(),
            &mut pool.0,
            || MultiFactory::new(ds, specs),
            |f, _idx, &(t, bi)| -> Result<MultiBatch> {
                let chunk = chunks[t].get(bi);
                let e = epoch as u64;
                match &self.tasks[t].head {
                    HeadKind::Nc => {
                        let loader = specs.nc.as_ref().unwrap();
                        let mut rng = Rng::seed_from(batch_seed(seed ^ NC_SALT, e, bi as u64));
                        let fac = f.nc.as_mut().unwrap();
                        let (batch, touch) =
                            build_nc_batch(fac, loader, chunk, &mut rng, (bi % nw) as u32, true)?;
                        Ok(MultiBatch::Nc(batch, touch))
                    }
                    HeadKind::Lp { .. } => {
                        let loader = specs.lp.as_ref().unwrap();
                        let mut rng = Rng::seed_from(batch_seed(seed ^ LP_SALT, e, bi as u64));
                        let fac = f.lp.as_mut().unwrap();
                        let (batch, touch) =
                            build_lp_batch(fac, loader, chunk, &mut rng, (bi % nw) as u32, true)?;
                        Ok(MultiBatch::Lp(batch, touch))
                    }
                    HeadKind::Distill => {
                        let dsp = specs.distill.as_ref().unwrap();
                        let store = ds.tokens[ds.target_ntype].as_ref().unwrap();
                        let mut rng =
                            Rng::seed_from(batch_seed(seed ^ DISTILL_SALT, e, bi as u64));
                        let fac = f.distill.as_mut().unwrap();
                        let db = build_distill_batch(
                            fac,
                            store,
                            ds.target_ntype,
                            chunk,
                            &mut rng,
                            &dsp.tshape,
                            &dsp.tspec,
                            &dsp.dims,
                        )?;
                        Ok(MultiBatch::Distill(db))
                    }
                }
            },
            |idx, batch| {
                let (t, bi) = items[idx];
                consume(t, bi, batch)
            },
        )?;
        Ok(counts)
    }

    /// Train all declared heads over the shared trunk; evaluate each
    /// head with its standalone evaluator at the end.
    pub fn fit(&self, rt: &Runtime, ds: &mut GsDataset, opts: &TrainOptions) -> Result<MultiReport> {
        self.validate()?;
        let ds: &GsDataset = ds; // embedding updates go through interior mutability
        let arch = &self.arch;
        let nc_train = format!("{arch}_nc_train");
        let nc_logits = format!("{arch}_nc_logits");
        // The distill teacher is the run's NC head, so its emb
        // artifact must match the NC arch (the student's MSE target
        // width is checked against it in DistillDims::derive).
        let teacher_emb = format!("{arch}_nc_emb");
        let dt = DistillTrainer::default();
        let mut lp_artifact = String::new();

        // Resolve per-head specs + device states.
        let mut specs = MultiSpecs { nc: None, lp: None, distill: None };
        let mut heads: Vec<Head> = Vec::with_capacity(self.tasks.len());
        for t in &self.tasks {
            match &t.head {
                HeadKind::Nc => {
                    let spec = rt.manifest.get(&nc_train)?.clone();
                    let enc = EncoderStep::from_spec(&spec);
                    specs.nc = Some(NodeDataLoader::new(&spec)?);
                    heads.push(Head::Nc { st: TrainState::new(rt, &nc_train)?, enc });
                }
                HeadKind::Lp { loss, sampler, .. } => {
                    lp_artifact = lp_train_artifact(*sampler);
                    let spec = rt.manifest.get(&lp_artifact)?.clone();
                    let enc = EncoderStep::from_spec(&spec);
                    specs.lp = Some(LinkPredictionDataLoader::new(&spec, *sampler)?);
                    heads.push(Head::Lp {
                        st: TrainState::new(rt, &lp_artifact)?,
                        enc,
                        sel: loss.sel(),
                    });
                }
                HeadKind::Distill => {
                    let spec = rt.manifest.get(&dt.distill_artifact)?.clone();
                    let tspec = rt.manifest.get(&teacher_emb)?.clone();
                    specs.distill = Some(DistillSpecs::derive(&spec, tspec)?);
                    heads.push(Head::Distill { st: TrainState::new(rt, &dt.distill_artifact)? });
                }
            }
        }

        let nc_idx = self.index_of("nc");
        let mut shuffles = self.shuffle_rngs(opts.seed);
        let mut report = MultiReport {
            names: self.tasks.iter().map(|t| t.head.name().to_string()).collect(),
            epoch_losses: vec![vec![]; self.tasks.len()],
            steps: vec![0; self.tasks.len()],
            ..Default::default()
        };

        // Per-worker factories pinned across epochs.
        let mut fpool = MultiFactoryPool::default();
        for epoch in 0..opts.epochs {
            let _sp = crate::span!("trainer.multi.epoch", epoch = epoch);
            // The distill teacher tracks the NC head: a session over
            // its parameters, frozen for the epoch (deterministic and
            // cheap — one params_host per epoch).
            let tsess = if specs.distill.is_some() {
                let Some(Head::Nc { st, .. }) = nc_idx.map(|i| &heads[i]) else {
                    bail!("distill head validated to require an nc head");
                };
                Some(InferSession::new(rt, &teacher_emb, &st.params_host()?)?)
            } else {
                None
            };
            let mut loss = vec![0.0f32; self.tasks.len()];
            let mut steps = vec![0usize; self.tasks.len()];
            self.epoch_batches_pooled(ds, &specs, opts, epoch, &mut shuffles, &mut fpool, |t, bi, mb| {
                let lr = self.tasks[t].lr.unwrap_or(opts.lr);
                let worker = (bi % opts.n_workers.max(1)) as u32;
                let l = match (mb, &mut heads[t]) {
                    (MultiBatch::Nc(mut batch, touch), Head::Nc { st, enc }) => {
                        enc.step(rt, ds, st, &[lr], &mut batch, &touch, worker)?.loss
                    }
                    (MultiBatch::Lp(mut batch, touch), Head::Lp { st, enc, sel }) => {
                        enc.step(rt, ds, st, &[lr, *sel], &mut batch, &touch, worker)?.loss
                    }
                    (MultiBatch::Distill(db), Head::Distill { st }) => {
                        let dsp = specs.distill.as_ref().unwrap();
                        let tsess = tsess.as_ref().expect("distill head implies a teacher");
                        distill_student_step(rt, ds, tsess, st, db, &dsp.dims, lr)?
                    }
                    _ => bail!("batch routed to the wrong head"),
                };
                loss[t] += l;
                steps[t] += 1;
                Ok(())
            })?;
            for t in 0..self.tasks.len() {
                report.epoch_losses[t].push(loss[t] / steps[t].max(1) as f32);
                report.steps[t] += steps[t];
            }
            if opts.verbose {
                let parts: Vec<String> = self
                    .tasks
                    .iter()
                    .enumerate()
                    .map(|(t, ts)| {
                        format!(
                            "{} {:.4} ({} steps)",
                            ts.head.name(),
                            report.epoch_losses[t].last().unwrap(),
                            steps[t]
                        )
                    })
                    .collect();
                crate::gs_info!("multi", "epoch {epoch}: {}", parts.join(" | "));
            }
        }
        for (t, ts) in self.tasks.iter().enumerate() {
            crate::obs::metrics::gauge_set(
                &format!("trainer.multi.{}.loss", ts.head.name()),
                report.epoch_losses[t].last().copied().unwrap_or(0.0) as f64,
            );
        }

        // Per-head evaluation through the standalone evaluators (the
        // shared forward path), so multi-task metrics are directly
        // comparable to single-task reports.
        for (t, task) in self.tasks.iter().enumerate() {
            match (&task.head, &heads[t]) {
                (HeadKind::Nc, Head::Nc { st, .. }) => {
                    let trainer = NodeTrainer::new(&nc_train, &nc_logits);
                    let mut r = NcReport {
                        epoch_losses: report.epoch_losses[t].clone(),
                        steps: report.steps[t],
                        ..Default::default()
                    };
                    r.val_acc = trainer.evaluate(rt, ds, st, Split::Val, opts)?;
                    r.test_acc = trainer.evaluate(rt, ds, st, Split::Test, opts)?;
                    report.nc = Some(r);
                }
                (HeadKind::Lp { loss, sampler, .. }, Head::Lp { st, .. }) => {
                    let trainer =
                        LpTrainer::new(&lp_artifact, LP_EMB_ARTIFACT, *loss, *sampler);
                    // Validation runs once, after training — best-epoch
                    // tracking doesn't happen here, so report the same
                    // placeholder the standalone trainer reports with
                    // `eval_every_epoch = false` (not a fake peak).
                    let mut r = LpReport {
                        epoch_losses: report.epoch_losses[t].clone(),
                        steps: report.steps[t],
                        best_epoch: 1,
                        ..Default::default()
                    };
                    r.val_mrr = trainer.evaluate(rt, ds, st, Split::Val, opts)?;
                    r.test_mrr = trainer.evaluate(rt, ds, st, Split::Test, opts)?;
                    report.lp = Some(r);
                }
                (HeadKind::Distill, Head::Distill { .. }) => {
                    report.distill_mse = report.epoch_losses[t].last().copied();
                }
                _ => unreachable!("heads built in task order"),
            }
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_and_exhaustive() {
        let counts = [7usize, 3, 5];
        let weights = [2.0, 1.0, 1.0];
        let a = build_schedule(11, 0, &counts, &weights);
        let b = build_schedule(11, 0, &counts, &weights);
        assert_eq!(a, b);
        assert_eq!(a.len(), 15);
        for (t, &c) in counts.iter().enumerate() {
            assert_eq!(a.iter().filter(|&&x| x == t).count(), c, "task {t}");
        }
        // Epoch and seed both move the schedule.
        assert_ne!(a, build_schedule(11, 1, &counts, &weights));
        assert_ne!(a, build_schedule(12, 0, &counts, &weights));
    }

    #[test]
    fn schedule_weights_bias_early_slots() {
        // With a 10x weight, the heavy task should dominate the first
        // half of the schedule (its budget allows it).
        let counts = [20usize, 20];
        let weights = [10.0, 1.0];
        let s = build_schedule(3, 0, &counts, &weights);
        let early = s[..10].iter().filter(|&&t| t == 0).count();
        assert!(early >= 7, "heavy task got only {early}/10 early slots");
    }

    #[test]
    fn validate_rejects_bad_task_sets() {
        let t = MultiTaskTrainer::new("rgcn", vec![]);
        assert!(t.validate().is_err());
        let t = MultiTaskTrainer::new(
            "rgcn",
            vec![TaskSpec::new(HeadKind::Nc), TaskSpec::new(HeadKind::Nc)],
        );
        assert!(t.validate().unwrap_err().to_string().contains("duplicate"));
        let t = MultiTaskTrainer::new("rgcn", vec![TaskSpec::new(HeadKind::Distill)]);
        assert!(t.validate().unwrap_err().to_string().contains("teacher"));
        let mut bad = TaskSpec::new(HeadKind::Nc);
        bad.weight = 0.0;
        let t = MultiTaskTrainer::new("rgcn", vec![bad]);
        assert!(t.validate().is_err());
    }
}
