//! Serving benches (`scripts/bench.sh` → `BENCH_serve.json`): engine
//! forward latency, steady-state allocation audit, offline-inference
//! throughput, cached-vs-uncached hot-seed throughput, and closed-loop
//! Zipf traffic through the micro-batcher with latency percentiles.
//!
//! Runs end-to-end without AOT artifacts: execution falls back to the
//! deterministic surrogate backend (gated like everywhere else), so
//! sampling + assembly + caching are always measured.  Three
//! assertions encode the serving acceptance criteria:
//!   1. sample+assemble through the recycled-buffer ring performs ZERO
//!      steady-state heap allocations (counting global allocator);
//!   2. a warmed cache serves hot seeds with ≥ 2x the uncached
//!      steady-state throughput;
//!   3. warmed-cache predictions are bit-identical to uncached
//!      recompute.

#[path = "common.rs"]
mod common;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use graphstorm::dataloader::{BatchFactory, LembTouch};
use graphstorm::runtime::Tensor;
use graphstorm::serve::{
    cache_key, closed_loop, EmbeddingCache, EnginePoolCfg, InferenceEngine, MicroBatcherCfg,
    OfflineInference, ShardedCache, Zipf,
};
use graphstorm::util::Rng;

/// Counting allocator: every alloc/realloc bumps a global counter so
/// the steady-state loops below can assert "no allocation".
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn write_json(results: &[(String, f64)]) {
    let path =
        std::env::var("GS_SERVE_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let mut body = String::from("{\n");
    for (i, (name, v)) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        body.push_str(&format!("  \"{name}\": {v:.4}{comma}\n"));
    }
    body.push_str("}\n");
    match std::fs::write(&path, &body) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    println!("=== serve benches ===");
    let mut results: Vec<(String, f64)> = vec![];
    // Workload parameters live in scripts/bench_serve.json (versioned)
    // rather than shell flags; GS_BENCH_CONF overrides the path.
    let conf = common::BenchConf::load(&[
        "mag_papers",
        "shard_size",
        "hot_requests",
        "zipf_requests",
        "alpha",
        "clients",
        "cache",
        "max_batch",
        "deadline_us",
        "pool_workers",
        "pool_requests",
        "shards",
        "shard_requests",
    ]);
    let mut ds = common::mag_dataset(common::scale(conf.usize("mag_papers", 2000)), 1);
    ds.ensure_text_features(64);
    let nt = ds.target_ntype as u32;
    let n_nodes = ds.graph.num_nodes[nt as usize];

    // Engine: real artifact when PJRT executes, surrogate otherwise.
    let (engine, backend) = InferenceEngine::auto(&ds, "rgcn", 8, 7).unwrap();
    println!("backend: {backend}");
    let c = engine.out_dim();

    // ---- engine forward latency -----------------------------------------
    let mut sc = engine.make_scratch();
    let seeds32: Vec<(u32, u32)> = (0..32u32).map(|i| (nt, i % n_nodes as u32)).collect();
    for _ in 0..3 {
        engine.forward(&mut sc, &seeds32).unwrap();
    }
    let iters = 50;
    let t0 = Instant::now();
    for _ in 0..iters {
        let rows = engine.forward(&mut sc, &seeds32).unwrap();
        std::hint::black_box(rows.len());
    }
    let fwd_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
    println!("forward (32 seeds)                mean {fwd_ms:>9.3} ms");
    results.push(("forward32_mean_ms".into(), fwd_ms));

    // ---- steady-state allocation audit ----------------------------------
    // Canonical sample + assembly through the double-buffer ring must
    // not allocate once warm (satellite: buffer reuse in
    // assemble_block_inputs).
    {
        let spec = engine.spec.clone();
        let shape = engine.shape.clone();
        let mut f = BatchFactory::new(&ds, &shape);
        let mut ring: [(Vec<Tensor>, LembTouch); 2] = [(vec![], vec![]), (vec![], vec![])];
        let mut flip = 0usize;
        let seeds: Vec<(u32, u32)> = (0..64u32).map(|i| (nt, i % n_nodes as u32)).collect();
        for _ in 0..6 {
            flip ^= 1;
            let (out, touch) = &mut ring[flip];
            f.sample_assemble_canonical_into(&seeds, &shape, &spec, 7, 0, out, touch).unwrap();
        }
        let before = ALLOCS.load(Ordering::Relaxed);
        let loops = 100;
        let t0 = Instant::now();
        for _ in 0..loops {
            flip ^= 1;
            let (out, touch) = &mut ring[flip];
            f.sample_assemble_canonical_into(&seeds, &shape, &spec, 7, 0, out, touch).unwrap();
            std::hint::black_box(out.len());
        }
        let asm_ms = t0.elapsed().as_secs_f64() * 1e3 / loops as f64;
        let delta = ALLOCS.load(Ordering::Relaxed) - before;
        println!("sample+assemble ring (64 seeds)   mean {asm_ms:>9.3} ms   allocs/iter {}", delta as f64 / loops as f64);
        results.push(("assemble_ring_mean_ms".into(), asm_ms));
        results.push(("assemble_steady_allocs".into(), delta as f64));
        assert_eq!(delta, 0, "steady-state sample+assemble must not allocate");
    }

    // ---- offline inference + shard round-trip ---------------------------
    let tmp = std::env::temp_dir().join(format!("gs_serve_bench_{}", std::process::id()));
    let off = OfflineInference { shard_size: conf.usize("shard_size", 1024), ..Default::default() };
    let rep = off.run(&engine, nt, &tmp).unwrap();
    let rows_per_s = rep.rows as f64 / rep.secs.max(1e-9);
    println!(
        "offline inference                 {} rows in {:.2}s ({rows_per_s:.0} rows/s, {} shards)",
        rep.rows,
        rep.secs,
        rep.shards.len()
    );
    results.push(("offline_rows_per_s".into(), rows_per_s));

    // ---- hot-seed throughput: uncached vs warmed cache ------------------
    // The acceptance bar: a warmed cache must serve hot seeds with
    // >= 2x uncached steady-state throughput, bit-identically.
    {
        let hot: Vec<(u32, u32)> = (0..16u32).map(|i| (nt, i)).collect();
        let n_req = conf.usize("hot_requests", 4000);
        let mut rng = Rng::seed_from(9);
        let trace: Vec<(u32, u32)> = (0..n_req).map(|_| hot[rng.gen_range(hot.len())]).collect();

        // Uncached arm: every request recomputes through the engine.
        for &(nt, id) in &hot {
            engine.predict_one(&mut sc, nt, id).unwrap(); // warm scratch
        }
        let t0 = Instant::now();
        for &(nt, id) in &trace {
            let row = engine.forward(&mut sc, &[(nt, id)]).unwrap();
            std::hint::black_box(row[0]);
        }
        let uncached_rps = n_req as f64 / t0.elapsed().as_secs_f64();

        // Warmed arm: cache preloaded from the offline shards.
        let mut cache = EmbeddingCache::new(4096);
        let warmed = cache.warm_from_dir(&tmp, nt, engine.generation()).unwrap();
        assert!(warmed > 0 && !cache.is_empty());
        let t0 = Instant::now();
        let mut misses = 0usize;
        for &(nt, id) in &trace {
            match cache.get(cache_key(nt, id)) {
                Some(row) => std::hint::black_box(row[0]),
                None => {
                    misses += 1;
                    let row = engine.forward(&mut sc, &[(nt, id)]).unwrap();
                    std::hint::black_box(row[0])
                }
            };
        }
        let cached_rps = n_req as f64 / t0.elapsed().as_secs_f64();

        // Bit-identity: shard-warmed rows == fresh recompute.
        for &(nt, id) in &hot {
            let cached = cache.get(cache_key(nt, id)).expect("hot row warmed").to_vec();
            let fresh = engine.predict_one(&mut sc, nt, id).unwrap();
            assert_eq!(cached, fresh, "cached row for ({nt},{id}) diverged");
            assert_eq!(cached.len(), c);
        }
        let speedup = cached_rps / uncached_rps;
        println!(
            "hot seeds (16 nodes, {n_req} reqs)    uncached {uncached_rps:>9.0} req/s   warmed {cached_rps:>9.0} req/s   speedup {speedup:.1}x   (misses {misses})"
        );
        results.push(("hot_uncached_rps".into(), uncached_rps));
        results.push(("hot_cached_rps".into(), cached_rps));
        results.push(("hot_speedup".into(), speedup));
        assert!(
            speedup >= 2.0,
            "warmed cache must serve hot seeds >= 2x faster (got {speedup:.2}x)"
        );
    }

    // ---- closed-loop Zipf traffic through the micro-batcher -------------
    // Single engine scratch (pool of 1): the PR-2 baseline numbers.
    {
        let n_req =
            if common::fast() { 1000 } else { conf.usize("zipf_requests", 4000) };
        let zipf = Zipf::new(n_nodes, conf.f64("alpha", 1.1));
        let mut rng = Rng::seed_from(11);
        let trace: Vec<(u32, u32)> =
            (0..n_req).map(|_| (nt, zipf.sample(&mut rng) as u32)).collect();
        let cfg = EnginePoolCfg {
            workers: 1,
            batcher: MicroBatcherCfg {
                max_batch: conf.usize("max_batch", 32),
                deadline: std::time::Duration::from_micros(conf.usize("deadline_us", 200) as u64),
            },
            ..Default::default()
        };
        let clients = conf.usize("clients", 4);

        let nocache = ShardedCache::new(0, 1);
        let (s0, replies0) =
            closed_loop(&engine, cfg.clone(), &nocache, &trace, clients).unwrap();
        let cache = ShardedCache::new(conf.usize("cache", 4096), conf.usize("shards", 4));
        cache.warm_from_dir(&tmp, nt, engine.generation()).unwrap();
        let (s1, replies1) = closed_loop(&engine, cfg, &cache, &trace, clients).unwrap();
        println!(
            "zipf closed-loop uncached         p50 {:>6.0}us p99 {:>6.0}us {:>8.0} req/s hit {:>5.1}%",
            s0.p50_us, s0.p99_us, s0.rps, 100.0 * s0.hit_rate
        );
        println!(
            "zipf closed-loop warmed           p50 {:>6.0}us p99 {:>6.0}us {:>8.0} req/s hit {:>5.1}%",
            s1.p50_us, s1.p99_us, s1.rps, 100.0 * s1.hit_rate
        );
        results.push(("zipf_uncached_p50_us".into(), s0.p50_us));
        results.push(("zipf_uncached_p99_us".into(), s0.p99_us));
        results.push(("zipf_uncached_rps".into(), s0.rps));
        results.push(("zipf_warmed_p50_us".into(), s1.p50_us));
        results.push(("zipf_warmed_p99_us".into(), s1.p99_us));
        results.push(("zipf_warmed_rps".into(), s1.rps));
        results.push(("zipf_warmed_hit_rate".into(), s1.hit_rate));

        // Determinism across arms, repeats and concurrency.
        let mut expected: std::collections::HashMap<(u32, u32), Vec<f32>> = Default::default();
        for (k, v) in replies0.into_iter().chain(replies1) {
            let e = expected.entry(k).or_insert_with(|| v.clone());
            assert_eq!(e, &v, "prediction for {k:?} diverged across arms/repeats");
        }
    }

    // ---- engine pool: pooled vs single-worker Zipf throughput -----------
    // The PR-4 acceptance bar: N engine scratches draining one queue
    // must serve the (uncached, compute-bound) Zipf workload at >= 2x
    // the single-worker rate, with bit-identical replies.  The assert
    // is gated on available cores like the PJRT benches are gated on
    // artifacts: below 4 cores a 2x parallel speedup isn't physical.
    {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let conf_workers = conf.usize("pool_workers", 0);
        let workers = if conf_workers == 0 { cores.clamp(2, 8) } else { conf_workers };
        let n_req = if common::fast() { 800 } else { conf.usize("pool_requests", 3000) };
        let zipf = Zipf::new(n_nodes, conf.f64("alpha", 1.1));
        let mut rng = Rng::seed_from(13);
        let trace: Vec<(u32, u32)> =
            (0..n_req).map(|_| (nt, zipf.sample(&mut rng) as u32)).collect();
        // Enough closed-loop clients to keep every worker's batch full.
        let clients = (workers * 8).clamp(16, 64);
        let mk = |w: usize| EnginePoolCfg {
            workers: w,
            batcher: MicroBatcherCfg {
                max_batch: 8,
                deadline: std::time::Duration::from_micros(100),
            },
            ..Default::default()
        };

        let c1 = ShardedCache::new(0, 1);
        let (serial, replies1) = closed_loop(&engine, mk(1), &c1, &trace, clients).unwrap();
        let cn = ShardedCache::new(0, 1);
        let (pooled, repliesn) =
            closed_loop(&engine, mk(workers), &cn, &trace, clients).unwrap();
        let speedup = pooled.rps / serial.rps.max(1e-9);
        println!(
            "zipf pool x1                      p50 {:>6.0}us p99 {:>6.0}us {:>8.0} req/s",
            serial.p50_us, serial.p99_us, serial.rps
        );
        println!(
            "zipf pool x{workers} ({cores} cores)            p50 {:>6.0}us p99 {:>6.0}us {:>8.0} req/s   speedup {speedup:.2}x",
            pooled.p50_us, pooled.p99_us, pooled.rps
        );
        results.push(("pool_workers".into(), workers as f64));
        results.push(("pool_serial_rps".into(), serial.rps));
        results.push(("pool_pooled_rps".into(), pooled.rps));
        results.push(("pool_speedup".into(), speedup));

        // Pooled replies are bit-identical to serial replies.
        let mut expected: std::collections::HashMap<(u32, u32), Vec<f32>> = Default::default();
        for (k, v) in replies1 {
            expected.entry(k).or_insert(v);
        }
        for (k, v) in repliesn {
            assert_eq!(expected.get(&k), Some(&v), "pooled prediction for {k:?} != serial");
        }
        if cores >= 4 && workers >= 4 {
            assert!(
                speedup >= 2.0,
                "engine pool must serve >= 2x single-worker on {cores} cores (got {speedup:.2}x)"
            );
        } else {
            println!("(pool speedup assert skipped: {cores} cores, {workers} workers)");
        }
    }

    // ---- striped cache vs single lock: warmed Zipf reads ----------------
    // The sharding acceptance bar: N cache stripes must serve a
    // fully-warmed Zipf read workload from T concurrent threads at
    // >= 2x the single-stripe (one global lock) rate.  The traffic is
    // pure cache hits — the engine is out of the loop — so the
    // measurement isolates lock contention, and the striped rows must
    // be bit-identical to the single-lock rows (replies are
    // shard-count-invariant by contract).
    {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let threads = cores.clamp(2, 8);
        let shards = conf.usize("shards", 4);
        let n_gets =
            if common::fast() { 50_000 } else { conf.usize("shard_requests", 200_000) };
        let zipf = Zipf::new(n_nodes, conf.f64("alpha", 1.1));
        let mut rng = Rng::seed_from(17);
        let trace: Vec<u64> =
            (0..n_gets).map(|_| cache_key(nt, zipf.sample(&mut rng) as u32)).collect();

        // 4x headroom so an uneven hash split across stripes can never
        // evict a warmed row (per-stripe capacity is total/shards).
        let single = ShardedCache::new(4 * n_nodes, 1);
        let striped = ShardedCache::new(4 * n_nodes, shards);
        assert!(single.warm_from_dir(&tmp, nt, engine.generation()).unwrap() > 0);
        assert!(striped.warm_from_dir(&tmp, nt, engine.generation()).unwrap() > 0);
        for id in 0..n_nodes as u32 {
            assert_eq!(
                single.get(cache_key(nt, id)),
                striped.get(cache_key(nt, id)),
                "striped row for node {id} diverged from the single-lock row"
            );
        }

        let run = |cache: &ShardedCache| {
            let t0 = Instant::now();
            std::thread::scope(|scope| {
                for chunk in trace.chunks(n_gets.div_ceil(threads)) {
                    scope.spawn(move || {
                        for &k in chunk {
                            let row = cache.get(k).expect("warmed cache never misses");
                            std::hint::black_box(row.len());
                        }
                    });
                }
            });
            n_gets as f64 / t0.elapsed().as_secs_f64()
        };
        let single_rps = run(&single);
        let striped_rps = run(&striped);
        let speedup = striped_rps / single_rps.max(1e-9);
        println!(
            "zipf reads 1 stripe ({threads} threads)    {single_rps:>12.0} get/s",
        );
        println!(
            "zipf reads {shards} stripes ({threads} threads)   {striped_rps:>12.0} get/s   speedup {speedup:.2}x",
        );
        results.push(("shard_count".into(), shards as f64));
        results.push(("shard_single_rps".into(), single_rps));
        results.push(("shard_striped_rps".into(), striped_rps));
        results.push(("shard_speedup".into(), speedup));
        if cores >= 4 && shards >= 4 {
            assert!(
                speedup >= 2.0,
                "striped cache must serve >= 2x single-lock on {cores} cores (got {speedup:.2}x)"
            );
        } else {
            println!("(shard speedup assert skipped: {cores} cores, {shards} shards)");
        }
    }

    // ---- disabled-tracing overhead --------------------------------------
    // The obs contract (docs/OBSERVABILITY.md): an un-traced run pays
    // one relaxed atomic load per span!/event! site and never
    // evaluates field expressions.  Pin the per-site cost, then bound
    // the worst-case per-batch overhead (~4 sites fire per served
    // batch: dispatch, forward, reply, queue timing) against the
    // measured 32-seed forward — it must stay under 1%.
    {
        graphstorm::obs::trace::set_enabled(false);
        let iters = 1_000_000u64;
        let t0 = Instant::now();
        for i in 0..iters {
            let _s = graphstorm::span!("bench.disabled", i = i);
            graphstorm::event!("bench.disabled.event", i = i);
            std::hint::black_box(&_s);
        }
        let ns_per_site = t0.elapsed().as_secs_f64() * 1e9 / (2.0 * iters as f64);
        let overhead = 4.0 * ns_per_site / (fwd_ms * 1e6);
        println!(
            "disabled span/event               {ns_per_site:>9.2} ns/site   ({:.5}% of a batch forward)",
            overhead * 100.0
        );
        results.push(("disabled_span_ns".into(), ns_per_site));
        results.push(("disabled_span_overhead_frac".into(), overhead));
        assert!(
            overhead < 0.01,
            "disabled tracing must cost < 1% of a batch forward (got {:.3}%)",
            overhead * 100.0
        );
    }

    std::fs::remove_dir_all(&tmp).ok();
    write_json(&results);
}
