//! Figure 5 — jointly modeling text and graph on MAG (bar chart).
//!
//! Paper bars (venue-prediction accuracy): fine-tuned BERT alone ≪
//! pre-trained BERT+GNN < FTLP BERT+GNN < FTNC BERT+GNN (best, +17.6%
//! over pre-trained).  Prints the four bar values plus ASCII bars.

#[path = "common.rs"]
mod common;

use graphstorm::trainer::{LmTrainer, NodeTrainer, TrainOptions};

fn main() {
    let rt = common::runtime();
    let lm = LmTrainer::default();
    let n_papers = common::scale(2500);
    let nc_epochs = if common::fast() { 2 } else { 3 };
    let ft_epochs = if common::fast() { 1 } else { 2 };
    let mut bars: Vec<(&str, f64)> = vec![];

    // Common pre-trained LM.
    let base_ds = common::mag_dataset(n_papers, 1);
    let (_, mlm_st) = lm
        .pretrain_mlm(&rt, &base_ds, base_ds.target_ntype, &common::opts(1, 1))
        .unwrap();
    let mlm_params = mlm_st.params_host().unwrap();

    // Bar 1: fine-tuned BERT alone.
    {
        let ds = common::mag_dataset(n_papers, 1);
        let (_, st) = lm
            .finetune_nc(&rt, &ds, &mlm_params, &TrainOptions { epochs: ft_epochs + 1, ..common::opts(1, 1) })
            .unwrap();
        let acc = lm.evaluate_nc(&rt, &ds, &st, graphstorm::dataloader::Split::Test).unwrap();
        bars.push(("BERT (fine-tuned, no GNN)", acc));
    }

    // Bars 2-4: GNN over embeddings from {pre-trained, FTLP, FTNC} LM.
    for (name, mode) in [
        ("pre-trained BERT + GNN", "pre"),
        ("FTLP BERT + GNN", "lp"),
        ("FTNC BERT + GNN", "nc"),
    ] {
        let mut ds = common::mag_dataset(n_papers, 1);
        let params = match mode {
            "lp" => {
                let (_, st) = lm
                    .finetune_lp(&rt, &ds, &mlm_params, &common::opts(ft_epochs, 1))
                    .unwrap();
                st.params_host().unwrap()
            }
            "nc" => {
                let (_, st) = lm
                    .finetune_nc(&rt, &ds, &mlm_params, &common::opts(ft_epochs, 1))
                    .unwrap();
                st.params_host().unwrap()
            }
            _ => mlm_params.clone(),
        };
        lm.embed_all(&rt, &mut ds, &params, &common::opts(1, 1)).unwrap();
        let trainer = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_logits");
        let (rep, _) = trainer.fit(&rt, &mut ds, &common::opts(nc_epochs, 1)).unwrap();
        bars.push((name, rep.test_acc));
    }

    common::table_header("Figure 5: jointly modeling text and graph (MAG-like, venue accuracy)", &["Method", "Acc"]);
    let max = bars.iter().map(|b| b.1).fold(0.0, f64::max).max(1e-9);
    for (name, acc) in &bars {
        let w = ((acc / max) * 40.0).round() as usize;
        println!("{name:<28} | {:.4} | {}", acc, "#".repeat(w));
    }
    let ok = bars[0].1 <= bars[1].1 && bars[1].1 <= bars[3].1 && bars[2].1 <= bars[3].1 + 1e-9;
    println!(
        "\n[shape] BERT-alone <= pre+GNN <= FTNC+GNN and FTLP <= FTNC: {}",
        if ok { "OK" } else { "PARTIAL" }
    );
}
