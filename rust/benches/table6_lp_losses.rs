//! Table 6 — link prediction on Amazon Review: loss functions ×
//! negative-sampling methods.
//!
//! Paper rows: {contrastive, cross-entropy} × {in-batch, joint-1024,
//! joint-32, joint-4, uniform-32, uniform-1024(OOM)}; columns
//! epoch time / #epochs(to best) / MRR.  Expected shape:
//!   * contrastive ≫ CE at every K;
//!   * CE improves as K shrinks (joint-4 best CE row);
//!   * uniform sampling has the largest epoch time & remote traffic;
//!   * uniform with large K OOMs (the block's seed slots explode).
//! K values scale 1024→256 (the artifact ladder), batch 1024→32.

#[path = "common.rs"]
mod common;

use graphstorm::datagen::amazon::ArVariant;
use graphstorm::sampling::NegSampler;
use graphstorm::trainer::lp::{LpLoss, LpTrainer};

fn artifact_for(s: &NegSampler) -> Option<String> {
    match s {
        NegSampler::Uniform { k: 32 } => Some("rgcn_lp_uniform_k32_train".into()),
        NegSampler::Uniform { .. } => None, // OOM rows (paper: uniform-1024)
        s => Some(format!("rgcn_lp_joint_k{}_train", s.k())),
    }
}

fn main() {
    let rt = common::runtime();
    let n_items = common::scale(2500);
    let epochs = if common::fast() { 2 } else { 3 };

    let samplers = [
        NegSampler::InBatch { k: 32 },
        NegSampler::Joint { k: 256 },
        NegSampler::Joint { k: 32 },
        NegSampler::Joint { k: 4 },
        NegSampler::Uniform { k: 32 },
        NegSampler::Uniform { k: 256 },
    ];

    common::table_header(
        "Table 6: LP on AR-like — loss x negative sampling (batch 32; paper batch 1024)",
        &["Loss", "Neg-Sample", "epoch time", "#epochs", "MRR", "remote MB/epoch"],
    );
    let mut results: Vec<(String, String, f64, usize, f64, f64)> = vec![];
    for loss in [LpLoss::Contrastive, LpLoss::CrossEntropy] {
        for sampler in samplers {
            let Some(artifact) = artifact_for(&sampler) else {
                println!("{} | {} | - | OOM | - | -", loss.label(), sampler.label());
                results.push((loss.label().into(), sampler.label(), f64::NAN, 0, f64::NAN, f64::NAN));
                continue;
            };
            let mut ds = common::ar_dataset(n_items, ArVariant::HeteroV2, 2);
            ds.ensure_text_features(64);
            let mut tr = LpTrainer::new(&artifact, "rgcn_lp_emb", loss, sampler);
            tr.max_train_edges = Some(if common::fast() { 480 } else { 960 });
            ds.engine.counters.reset();
            let (rep, _) = tr.fit(&rt, &mut ds, &common::opts(epochs, 2)).unwrap();
            let traffic = ds.engine.counters.snapshot();
            let epoch_s = rep.epoch_times.iter().sum::<f64>() / rep.epoch_times.len() as f64;
            let mb = traffic.remote_bytes as f64 / 1e6 / epochs as f64;
            println!(
                "{} | {} | {:.2}s | {} | {:.4} | {:.1}",
                loss.label(),
                sampler.label(),
                epoch_s,
                rep.best_epoch,
                rep.val_mrr,
                mb
            );
            results.push((loss.label().into(), sampler.label(), epoch_s, rep.best_epoch, rep.val_mrr, mb));
        }
    }

    // Shape checks.
    let get = |l: &str, s: &str| results.iter().find(|r| r.0 == l && r.1 == s).cloned();
    if let (Some(cj), Some(xj)) = (get("contrastive", "joint-32"), get("cross-entropy", "joint-32")) {
        println!(
            "\n[shape] contrastive > CE at joint-32: {} ({:.3} vs {:.3})",
            if cj.4 > xj.4 { "OK" } else { "MISS" },
            cj.4,
            xj.4
        );
    }
    if let (Some(x4), Some(x256)) = (get("cross-entropy", "joint-4"), get("cross-entropy", "joint-256")) {
        println!(
            "[shape] CE better with fewer negatives: {} (joint-4 {:.3} vs joint-256 {:.3})",
            if x4.4 > x256.4 { "OK" } else { "MISS" },
            x4.4,
            x256.4
        );
    }
    if let (Some(u), Some(j)) = (get("contrastive", "uniform-32"), get("contrastive", "joint-32")) {
        println!(
            "[shape] uniform slower + more traffic than joint: {} (epoch {:.2}s vs {:.2}s; {:.1}MB vs {:.1}MB)",
            if u.2 > j.2 && u.5 > j.5 { "OK" } else { "MISS" },
            u.2,
            j.2,
            u.5,
            j.5
        );
    }
}
