//! Table 2 — overall performance and computation time of GraphStorm:
//! pre-trained vs fine-tuned BERT+GNN on MAG/AR for NC and LP.
//!
//! Pipeline per row (as in the paper): data processing → LM stage
//! (pre-trained = MLM only; fine-tuned = MLM + task fine-tune) →
//! compute LM embeddings for all text nodes ("LM Time Cost") → train
//! RGCN (epoch duration + final metric).  Expected *shape*: fine-tuned
//! beats pre-trained on every task; LP fine-tuning is the most
//! expensive stage (the paper's 2–3-day cell).

#[path = "common.rs"]
mod common;

use graphstorm::datagen::amazon::ArVariant;
use graphstorm::runtime::Tensor;
use graphstorm::sampling::NegSampler;
use graphstorm::trainer::lp::LpLoss;
use graphstorm::trainer::{LmTrainer, LpTrainer, NodeTrainer};

struct Row {
    dataset: &'static str,
    task: &'static str,
    data_s: f64,
    lm_s: f64,
    epoch_s: f64,
    metric: f64,
    mode: &'static str,
}

fn lm_params(
    rt: &graphstorm::runtime::Runtime,
    ds: &graphstorm::dataloader::GsDataset,
    finetune: Option<&str>,
    epochs: usize,
) -> (f64, Vec<(String, Tensor)>) {
    let lm = LmTrainer::default();
    let t0 = std::time::Instant::now();
    let (_, st) = lm
        .pretrain_mlm(rt, ds, ds.target_ntype, &common::opts(1, 1))
        .unwrap();
    let params = match finetune {
        Some("nc") => {
            let (_, st2) = lm
                .finetune_nc(rt, ds, &st.params_host().unwrap(), &common::opts(epochs, 1))
                .unwrap();
            st2.params_host().unwrap()
        }
        Some("lp") => {
            let (_, st2) = lm
                .finetune_lp(rt, ds, &st.params_host().unwrap(), &common::opts(epochs, 1))
                .unwrap();
            st2.params_host().unwrap()
        }
        _ => st.params_host().unwrap(),
    };
    (t0.elapsed().as_secs_f64(), params)
}

fn main() {
    let rt = common::runtime();
    let lm = LmTrainer::default();
    let mut rows: Vec<Row> = vec![];
    let nc_epochs = if common::fast() { 2 } else { 3 };

    for (dataset, is_mag) in [("MAG-like", true), ("AR-like", false)] {
        // Data processing stage (generate + partition + engine build).
        let t0 = std::time::Instant::now();
        let _base = if is_mag {
            common::mag_dataset(common::scale(2500), 2)
        } else {
            common::ar_dataset(common::scale(2000), ArVariant::HeteroV2, 2)
        };
        let data_s = t0.elapsed().as_secs_f64();

        for mode in ["pre-trained", "fine-tuned"] {
            // --- NC row ---
            let mut ds = if is_mag {
                common::mag_dataset(common::scale(2500), 2)
            } else {
                common::ar_dataset(common::scale(2000), ArVariant::HeteroV2, 2)
            };
            let (mut lm_s, params) = lm_params(
                &rt,
                &ds,
                (mode == "fine-tuned").then_some("nc"),
                if common::fast() { 1 } else { 2 },
            );
            lm_s += {
                let t = std::time::Instant::now();
                lm.embed_all(&rt, &mut ds, &params, &common::opts(1, 1)).unwrap();
                t.elapsed().as_secs_f64()
            };
            let trainer = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_logits");
            let (rep, _) = trainer.fit(&rt, &mut ds, &common::opts(nc_epochs, 2)).unwrap();
            rows.push(Row {
                dataset,
                task: "NC",
                data_s,
                lm_s,
                epoch_s: rep.epoch_times.iter().sum::<f64>() / rep.epoch_times.len() as f64,
                metric: rep.test_acc,
                mode,
            });

            // --- LP row ---
            let mut ds = if is_mag {
                common::mag_dataset(common::scale(2500), 2)
            } else {
                common::ar_dataset(common::scale(2000), ArVariant::HeteroV2, 2)
            };
            let (mut lm_s, params) = lm_params(
                &rt,
                &ds,
                (mode == "fine-tuned").then_some("lp"),
                if common::fast() { 1 } else { 2 },
            );
            lm_s += {
                let t = std::time::Instant::now();
                lm.embed_all(&rt, &mut ds, &params, &common::opts(1, 1)).unwrap();
                t.elapsed().as_secs_f64()
            };
            let mut trainer = LpTrainer::new(
                "rgcn_lp_joint_k32_train",
                "rgcn_lp_emb",
                LpLoss::Contrastive,
                NegSampler::Joint { k: 32 },
            );
            trainer.max_train_edges = Some(if common::fast() { 800 } else { 1600 });
            let (rep, _) = trainer
                .fit(&rt, &mut ds, &common::opts(if common::fast() { 2 } else { 3 }, 2))
                .unwrap();
            rows.push(Row {
                dataset,
                task: "LP",
                data_s,
                lm_s,
                epoch_s: rep.epoch_times.iter().sum::<f64>() / rep.epoch_times.len() as f64,
                metric: rep.test_mrr,
                mode,
            });
        }
    }

    common::table_header(
        "Table 2: overall performance + computation time (pre-trained vs fine-tuned LM + GNN)",
        &["Dataset", "Task", "DataProc", "Mode", "LM time", "Epoch", "Metric"],
    );
    for r in &rows {
        println!(
            "{} | {} | {} | {} | {} | {} | {:.4}",
            r.dataset,
            r.task,
            common::hms(r.data_s),
            r.mode,
            common::hms(r.lm_s),
            common::hms(r.epoch_s),
            r.metric
        );
    }
    // Shape checks mirrored in EXPERIMENTS.md.
    for dataset in ["MAG-like", "AR-like"] {
        for task in ["NC", "LP"] {
            let get = |mode: &str| {
                rows.iter()
                    .find(|r| r.dataset == dataset && r.task == task && r.mode == mode)
                    .map(|r| r.metric)
                    .unwrap_or(0.0)
            };
            let (p, f) = (get("pre-trained"), get("fine-tuned"));
            println!(
                "[shape] {dataset}/{task}: fine-tuned {f:.4} vs pre-trained {p:.4} -> {}",
                if f >= p { "OK (fine-tuned >= pre-trained)" } else { "MISS" }
            );
        }
    }
}
