//! Table 5 — GNN-embedding distillation on MAG.
//!
//! Paper rows: DistilBERT fine-tuned with venue labels (41.17%) vs
//! DistilBERT distilled from a GNN teacher's embeddings (44.53%);
//! evaluation trains an MLP probe on each model's embeddings.
//! Expected shape: distilled > label-fine-tuned (~+8% relative).

#[path = "common.rs"]
mod common;

use graphstorm::trainer::{DistillTrainer, LmTrainer, NodeTrainer, TrainOptions};

fn main() {
    let rt = common::runtime();
    let mut ds = common::mag_dataset(common::scale(2500), 1);
    ds.ensure_text_features(64);

    // Teacher: RGCN trained on venue labels (bag-of-token text inputs).
    let nc = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_emb" /* placeholder */);
    let nc = NodeTrainer::new(&nc.train_artifact, "rgcn_nc_logits");
    let teacher_epochs = if common::fast() { 2 } else { 5 };
    let (teacher_rep, teacher_st) = nc.fit(&rt, &mut ds, &common::opts(teacher_epochs, 1)).unwrap();
    let teacher_params = teacher_st.params_host().unwrap();
    eprintln!("[table5] teacher test acc {:.4}", teacher_rep.test_acc);

    let opts = TrainOptions { epochs: if common::fast() { 1 } else { 3 }, ..common::opts(3, 1) };
    let dt = DistillTrainer::default();
    let lm = LmTrainer {
        nc_artifact: "student_nc_train".into(),
        ..Default::default()
    };

    // All papers the probe will see.
    let ids: Vec<u32> = (0..ds.graph.num_nodes[ds.target_ntype] as u32).collect();
    let probe_ids: Vec<u32> = ids.iter().copied().take(2000).collect();

    // Baseline: student LM fine-tuned on venue labels directly.
    let (_, base_st) = lm.finetune_nc(&rt, &ds, &[], &opts).unwrap();
    let (base_emb, bh) = dt
        .student_embeddings(&rt, &ds, "student_embed", &base_st.params_host().unwrap(), &probe_ids)
        .unwrap();
    let base_acc = dt.probe_accuracy(&rt, &ds, &base_emb, bh, &probe_ids, &opts).unwrap();

    // Distilled: student LM matched to the GNN teacher's embeddings.
    let (mse, dist_st) = dt.distill(&rt, &ds, &teacher_params, &opts).unwrap();
    let (dist_emb, dh) = dt
        .student_embeddings(&rt, &ds, "distill_embed", &dist_st.params_host().unwrap(), &probe_ids)
        .unwrap();
    let dist_acc = dt.probe_accuracy(&rt, &ds, &dist_emb, dh, &probe_ids, &opts).unwrap();

    common::table_header(
        "Table 5: GNN embedding distillation on MAG-like (MLP-probe accuracy)",
        &["Setting", "Acc"],
    );
    println!("Student LM fine-tuned with venue labels | {:.2}%", base_acc * 100.0);
    println!("Student LM with GNN distillation (final MSE {mse:.4}) | {:.2}%", dist_acc * 100.0);
    println!(
        "\n[shape] distilled > label-fine-tuned: {} ({:.1}% vs {:.1}%, paper 44.5% vs 41.2%)",
        if dist_acc > base_acc { "OK" } else { "MISS" },
        dist_acc * 100.0,
        base_acc * 100.0
    );
}
