//! Table 4 — performance on the Amazon Review graph varying schemas.
//!
//! Paper rows: Homogeneous (items only) → Hetero-v1 (+review) →
//! Hetero-v2 (+featureless customer).  Expected shape: LP MRR improves
//! monotonically; NC Acc improves at +review but NOT at +customer
//! (customers carry no brand signal).

#[path = "common.rs"]
mod common;

use graphstorm::datagen::amazon::ArVariant;
use graphstorm::sampling::NegSampler;
use graphstorm::trainer::lp::LpLoss;
use graphstorm::trainer::{LpTrainer, NodeTrainer};

fn main() {
    let rt = common::runtime();
    let n_items = common::scale(2500);
    let nc_epochs = if common::fast() { 3 } else { 6 };
    let lp_epochs = if common::fast() { 3 } else { 4 };

    let mut rows = vec![];
    for (variant, name, featureless) in [
        (ArVariant::Homogeneous, "Homogeneous (item)", "No"),
        (ArVariant::HeteroV1, "Heterogeneous-v1 (+review)", "No"),
        (ArVariant::HeteroV2, "Heterogeneous-v2 (+customer)", "\"customer\""),
    ] {
        // LP.
        let mut ds = common::ar_dataset(n_items, variant, 1);
        ds.ensure_text_features(64);
        let mut lp = LpTrainer::new(
            "rgcn_lp_joint_k32_train",
            "rgcn_lp_emb",
            LpLoss::Contrastive,
            NegSampler::Joint { k: 32 },
        );
        lp.max_train_edges = Some(if common::fast() { 800 } else { 2400 });
        let (lp_rep, _) = lp.fit(&rt, &mut ds, &common::opts(lp_epochs, 1)).unwrap();

        // NC.
        let mut ds = common::ar_dataset(n_items, variant, 1);
        ds.ensure_text_features(64);
        let nc = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_logits");
        let (nc_rep, _) = nc.fit(&rt, &mut ds, &common::opts(nc_epochs, 1)).unwrap();

        rows.push((name, featureless, lp_rep.test_mrr, nc_rep.test_acc));
    }

    common::table_header(
        "Table 4: Amazon-Review-like graph, varying schema",
        &["Schema", "featureless", "LP (MRR)", "NC (Acc)"],
    );
    for (name, fl, mrr, acc) in &rows {
        println!("{name} | {fl} | {mrr:.4} | {acc:.4}");
    }
    let (m0, m1, m2) = (rows[0].2, rows[1].2, rows[2].2);
    let (a0, a1, a2) = (rows[0].3, rows[1].3, rows[2].3);
    println!(
        "\n[shape] LP monotone: {} ({m0:.3} <= {m1:.3} <= {m2:.3})",
        if m0 <= m1 + 1e-3 && m1 <= m2 + 1e-3 { "OK" } else { "MISS" }
    );
    println!(
        "[shape] NC: +review helps ({}: {a0:.3} -> {a1:.3}); +customer does not ({}: {a1:.3} -> {a2:.3})",
        if a1 > a0 { "OK" } else { "MISS" },
        if a2 <= a1 + 0.02 { "OK" } else { "MISS" }
    );
}
