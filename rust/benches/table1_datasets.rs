//! Table 1 — statistics of the benchmark datasets.
//!
//! Paper: MAG (484.5M nodes / 7.52B edges, 4/4 types) and Amazon Review
//! (286.5M / 1.05B, 3/4 types) with NC/LP train-set sizes and
//! text-feature node counts.  Here: the synthetic MAG-like and AR-like
//! datasets at the scaled-down sizes every other bench uses.

#[path = "common.rs"]
mod common;

use graphstorm::datagen::amazon::ArVariant;
use graphstorm::dataloader::Split;

fn main() {
    let mag = common::mag_dataset(common::scale(4000), 1);
    let ar = common::ar_dataset(common::scale(3000), ArVariant::HeteroV2, 1);

    common::table_header(
        "Table 1: benchmark dataset statistics (scaled ~10^5x from the paper)",
        &["Dataset", "#nodes", "#edges", "#node/edge types", "NC train", "LP train", "text nodes"],
    );
    for (name, ds) in [("MAG-like", &mag), ("Amazon-Review-like", &ar)] {
        let s = ds.graph.stats();
        let nc_train = ds.node_labels().ids_in(Split::Train).len();
        let lp_train = ds.lp.as_ref().map(|l| l.edge_ids_in(Split::Train).len()).unwrap_or(0);
        let text_nodes: usize = ds
            .tokens
            .iter()
            .filter_map(|t| t.as_ref().map(|t| t.num_rows()))
            .sum();
        println!(
            "{name} | {} | {} | {}/{} | {} | {} | {}",
            s.num_nodes, s.num_edges, s.num_ntypes, s.num_etypes, nc_train, lp_train, text_nodes
        );
    }
    println!("\n(paper: MAG 484,511,504 nodes / 7,520,311,838 edges; AR 286,462,374 / 1,053,940,310)");
}
