//! Shared helpers for the paper-table bench harnesses.
//!
//! Each bench binary regenerates one table/figure of the paper at the
//! scaled-down workload (DESIGN.md §3) and prints rows in the paper's
//! format.  `GS_BENCH_FAST=1` shrinks workloads further for smoke runs.

#![allow(dead_code)]

use graphstorm::datagen::{self, amazon, mag, scale_free};
use graphstorm::dataloader::GsDataset;
use graphstorm::partition::{random_partition, PartitionBook};
use graphstorm::runtime::Runtime;
use graphstorm::trainer::TrainOptions;

pub fn fast() -> bool {
    std::env::var("GS_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Bench workload parameters from a versioned JSON file
/// (`GS_BENCH_CONF`, pointed at `scripts/bench_*.json` by
/// `scripts/bench.sh`); built-in defaults when unset.  Unknown keys
/// are hard errors with a nearest-key suggestion, like the run-config
/// layer.
pub struct BenchConf {
    doc: Option<graphstorm::util::json::Json>,
}

impl BenchConf {
    pub fn load(allowed: &[&str]) -> BenchConf {
        use graphstorm::util::json::Json;
        let Ok(path) = std::env::var("GS_BENCH_CONF") else {
            return BenchConf { doc: None };
        };
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read bench conf {path}: {e}"));
        let doc = Json::parse(&text).unwrap_or_else(|e| panic!("parse bench conf {path}: {e}"));
        let Some(m) = doc.as_obj() else { panic!("bench conf {path} must be a JSON object") };
        for k in m.keys() {
            assert!(
                allowed.contains(&k.as_str()),
                "unknown bench-conf key '{k}' in {path}{}; valid: {}",
                graphstorm::config::did_you_mean(k, allowed),
                allowed.join(", ")
            );
        }
        println!("bench conf: {path}");
        BenchConf { doc: Some(doc) }
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        match self.doc.as_ref().and_then(|d| d.get(key)) {
            None => default,
            Some(v) => v
                .as_f64()
                .filter(|f| f.fract() == 0.0 && *f >= 0.0)
                .map(|f| f as usize)
                .unwrap_or_else(|| {
                    panic!("bench-conf key '{key}' must be a non-negative integer")
                }),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        match self.doc.as_ref().and_then(|d| d.get(key)) {
            None => default,
            Some(v) => v
                .as_f64()
                .unwrap_or_else(|| panic!("bench-conf key '{key}' must be a number")),
        }
    }
}

pub fn scale(n: usize) -> usize {
    if fast() {
        (n / 4).max(200)
    } else {
        n
    }
}

pub fn mag_dataset(n_papers: usize, n_parts: usize) -> GsDataset {
    let raw = mag::generate(&mag::MagConfig { n_papers, ..Default::default() });
    let book = if n_parts <= 1 {
        PartitionBook::single(&raw.graph.num_nodes)
    } else {
        random_partition(&raw.graph, n_parts, 7)
    };
    datagen::build_dataset(raw, book, 64, 7)
}

pub fn ar_dataset(n_items: usize, variant: amazon::ArVariant, n_parts: usize) -> GsDataset {
    let world = amazon::generate_world(&amazon::ArConfig { n_items, ..Default::default() });
    let raw = amazon::build_variant(&world, variant);
    let book = if n_parts <= 1 {
        PartitionBook::single(&raw.graph.num_nodes)
    } else {
        random_partition(&raw.graph, n_parts, 7)
    };
    datagen::build_dataset(raw, book, 64, 7)
}

pub fn sf_dataset(n_edges: usize, n_parts: usize) -> (GsDataset, f64, f64) {
    let t0 = std::time::Instant::now();
    let raw = scale_free::generate(&scale_free::ScaleFreeConfig { n_edges, ..Default::default() });
    let gen_s = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let book = random_partition(&raw.graph, n_parts, 7);
    let part_s = t1.elapsed().as_secs_f64();
    (datagen::build_dataset(raw, book, 64, 7), gen_s, part_s)
}

pub fn opts(epochs: usize, n_workers: usize) -> TrainOptions {
    TrainOptions { lr: 3e-3, epochs, seed: 7, n_workers, ..Default::default() }
}

pub fn runtime() -> Runtime {
    Runtime::from_default_dir().expect("run `make artifacts` first")
}

/// Print a separator + table title in the paper's style.
pub fn table_header(title: &str, cols: &[&str]) {
    println!("\n=== {title} ===");
    println!("{}", cols.join(" | "));
    println!("{}", cols.iter().map(|c| "-".repeat(c.len())).collect::<Vec<_>>().join("-|-"));
}

pub fn hms(secs: f64) -> String {
    graphstorm::util::fmt_hms(secs)
}
