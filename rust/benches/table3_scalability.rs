//! Table 3 — scalability on synthetic power-law graphs.
//!
//! Paper: 1B/10B/100B edges on (4,8,8)/(8,16,16)/(16,32,32) instances;
//! stages data-preprocess / graph-partition / model-training, reported
//! in instance-minutes.  Here: 10^4-scaled graphs (100K/1M/10M edges)
//! with the same instance-count ladder; measured single-process stage
//! time + counted cross-partition traffic feed the cluster cost model,
//! and the scaling *factors* (instance-minute growth per 10× size) are
//! the reproduced shape.

#[path = "common.rs"]
mod common;

use graphstorm::dataloader::Split;
use graphstorm::dist::CostModel;
use graphstorm::trainer::NodeTrainer;

fn main() {
    let rt = common::runtime();
    let cm = CostModel::default();
    let sizes: &[(usize, usize, usize, usize, &str)] = if common::fast() {
        &[(100_000, 4, 8, 8, "100K"), (1_000_000, 8, 16, 16, "1M")]
    } else {
        &[
            (100_000, 4, 8, 8, "100K"),
            (1_000_000, 8, 16, 16, "1M"),
            (10_000_000, 16, 32, 32, "10M"),
        ]
    };

    common::table_header(
        "Table 3: scalability on synthetic graphs (paper sizes / 10^4)",
        &["Graph", "#inst(pre/part/train)", "Pre-process", "Partition", "Training",
          "inst-min (pre | part | train)"],
    );
    let mut inst_minutes: Vec<(f64, f64, f64)> = vec![];
    for &(edges, i_pre, i_part, i_train, label) in sizes {
        let (mut ds, gen_s, part_s) = common::sf_dataset(edges, i_part);
        // Train-set scaled like the paper (8M of 1B-edge graph ≈ 0.8%):
        // subsample the train split to 0.04% of edges (=> 400/4K/40K).
        let want_train = (edges / 250).min(40_000).max(400);
        {
            let labels = ds.labels[0].as_mut().unwrap();
            let mut seen = 0usize;
            for s in labels.split.iter_mut() {
                if *s == Split::Train {
                    seen += 1;
                    if seen > want_train {
                        *s = Split::None;
                    }
                }
            }
        }
        ds.engine.counters.reset();
        let t0 = std::time::Instant::now();
        let trainer = NodeTrainer::new("gcn_nc_train_fast", "gcn_nc_logits_fast");
        let epochs = 1;
        let (rep, _) = trainer.fit(&rt, &mut ds, &common::opts(epochs, i_train)).unwrap();
        let train_s = t0.elapsed().as_secs_f64();
        let traffic = ds.engine.counters.snapshot();

        // Cluster estimates: compute spread over instances + shuffle.
        let est_pre = cm.estimate(gen_s, 0, 1, i_pre);
        let est_part = cm.estimate(part_s, (edges * 8) as u64, 4, i_part);
        let est_train = cm.estimate(train_s, traffic.remote_bytes, rep.steps as u64, i_train);
        let im = (
            cm.instance_minutes(est_pre, i_pre),
            cm.instance_minutes(est_part, i_part),
            cm.instance_minutes(est_train, i_train),
        );
        inst_minutes.push(im);
        println!(
            "{label} | {i_pre}/{i_part}/{i_train} | {:.1}s | {:.1}s | {:.1}s ({} steps, acc {:.3}) | {:.2} | {:.2} | {:.2}",
            gen_s, part_s, train_s, rep.steps, rep.test_acc, im.0, im.1, im.2
        );
    }

    println!("\n[shape] instance-minute growth per 10x graph size (paper: 13x pre, ~14x part, ~11x train per 100x):");
    for w in inst_minutes.windows(2) {
        let g = (
            w[1].0 / w[0].0.max(1e-9),
            w[1].1 / w[0].1.max(1e-9),
            w[1].2 / w[0].2.max(1e-9),
        );
        println!(
            "  pre {:.1}x | part {:.1}x | train {:.1}x {}",
            g.0,
            g.1,
            g.2,
            if g.0 < 100.0 && g.2 < 100.0 { "(sub-quadratic: OK)" } else { "(MISS)" }
        );
    }
}
