//! Micro-benchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! neighbor sampling, batch assembly, partitioning, feature gather and
//! the full AOT train-step latency.  Hand-rolled harness (criterion is
//! unavailable offline): N warmup + M timed iterations, prints
//! mean/min per op.

#[path = "common.rs"]
mod common;

use graphstorm::dataloader::{assemble_block_inputs, NodeDataLoader, Split};
use graphstorm::partition::{metis_like_partition, random_partition};
use graphstorm::sampling::{BlockShape, EdgeExclusion, NeighborSampler};
use graphstorm::trainer::NodeTrainer;
use graphstorm::util::Rng;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    for _ in 0..3 {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::MAX, f64::min);
    println!("{name:<40} mean {:>9.3} ms   min {:>9.3} ms", mean * 1e3, min * 1e3);
}

fn main() {
    println!("=== micro benches (perf pass) ===");
    let rt = common::runtime();
    let mut ds = common::mag_dataset(common::scale(4000), 2);
    ds.ensure_text_features(64);
    let spec = rt.manifest.get("rgcn_nc_train").unwrap().clone();
    let shape = BlockShape::from_spec(&spec).unwrap();
    let sampler = NeighborSampler::new(&ds.graph);
    let train_ids = ds.node_labels().ids_in(Split::Train);
    let mut rng = Rng::seed_from(1);
    let seeds: Vec<(u32, u32)> = train_ids.iter().take(64).map(|&i| (0u32, i)).collect();

    bench("neighbor_sample (64 seeds, 2 hops)", 50, || {
        let b = sampler.sample_block(&seeds, &shape, &mut rng, &EdgeExclusion::new());
        std::hint::black_box(b.nodes.len());
    });

    let block = sampler.sample_block(&seeds, &shape, &mut rng, &EdgeExclusion::new());
    bench("assemble_block_inputs", 50, || {
        let (b, _) = assemble_block_inputs(&ds, &block, &spec, 0).unwrap();
        std::hint::black_box(b.len());
    });

    let loader = NodeDataLoader::new(&spec).unwrap();
    let chunk: Vec<u32> = train_ids.iter().take(64).copied().collect();
    bench("full NC batch build", 30, || {
        let (b, _, _) = loader.batch(&ds, &chunk, &mut rng, 0).unwrap();
        std::hint::black_box(b.len());
    });

    // AOT step latency (sample once, step many).
    let mut st = graphstorm::runtime::TrainState::new(&rt, "rgcn_nc_train").unwrap();
    let (batch, _, _) = loader.batch(&ds, &chunk, &mut rng, 0).unwrap();
    bench("rgcn_nc_train step (pallas)", 20, || {
        let o = st.step(&rt, &[3e-3], &batch).unwrap();
        std::hint::black_box(o.loss);
    });
    let spec_fast = rt.manifest.get("rgcn_nc_train_fast").unwrap().clone();
    let loader_fast = NodeDataLoader::new(&spec_fast).unwrap();
    let mut st2 = graphstorm::runtime::TrainState::new(&rt, "rgcn_nc_train_fast").unwrap();
    let (batch2, _, _) = loader_fast.batch(&ds, &chunk, &mut rng, 0).unwrap();
    bench("rgcn_nc_train step (xla scatter)", 20, || {
        let o = st2.step(&rt, &[3e-3], &batch2).unwrap();
        std::hint::black_box(o.loss);
    });

    // End-to-end epoch throughput.
    bench("NC epoch (train split)", 3, || {
        let trainer = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_logits");
        let mut ds2 = common::mag_dataset(1000, 1);
        ds2.ensure_text_features(64);
        let (r, _) = trainer.fit(&rt, &mut ds2, &common::opts(1, 1)).unwrap();
        std::hint::black_box(r.steps);
    });

    // Partitioners.
    let (dsf, _, _) = common::sf_dataset(200_000, 1);
    bench("random_partition (200K edges)", 10, || {
        let b = random_partition(&dsf.graph, 8, 3);
        std::hint::black_box(b.n_parts);
    });
    bench("metis_like_partition (200K edges)", 3, || {
        let b = metis_like_partition(&dsf.graph, 8, 3);
        std::hint::black_box(b.n_parts);
    });

    // Feature gather.
    let ids: Vec<u32> = (0..2304u32).map(|i| i % ds.graph.num_nodes[3] as u32).collect();
    bench("DistTensor gather 2304 x 64", 100, || {
        let v = ds.engine.features[3].gather(0, &ids);
        std::hint::black_box(v.len());
    });
}
