//! Micro-benchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! neighbor sampling, batch assembly, the serial-vs-prefetch pipeline,
//! partitioning, feature gather and the full AOT train-step latency.
//! Hand-rolled harness (criterion is unavailable offline): warmup +
//! timed iterations, prints mean/min per op and writes every entry to
//! `BENCH_micro.json` (path override: `GS_BENCH_OUT`) so the perf
//! trajectory is machine-readable across PRs.
//!
//! Runtime-dependent benches (PJRT steps) are skipped gracefully when
//! artifacts or the PJRT plugin are missing; the sampling/pipeline
//! benches always run — the pipeline consumer falls back to a
//! simulated device step in that case.

#[path = "common.rs"]
mod common;

use graphstorm::dataloader::{
    assemble_block_inputs, assemble_block_inputs_into, batch_seed, build_nc_batch, fill_lemb,
    run_pipeline, AssembleScratch, BatchFactory, LembTouch, NodeDataLoader, PrefetchConfig, Split,
};
use graphstorm::partition::{metis_like_partition, random_partition};
use graphstorm::runtime::{runtime_if_available, ArtifactSpec, Runtime};
use graphstorm::sampling::{Block, BlockShape, EdgeExclusion, NeighborSampler, SamplerScratch};
use graphstorm::trainer::NodeTrainer;
use graphstorm::util::Rng;

/// (name, mean ms, min ms) per benchmark, dumped as JSON at exit.
type Results = Vec<(String, f64, f64)>;

fn bench<F: FnMut()>(results: &mut Results, name: &str, iters: usize, mut f: F) {
    for _ in 0..3 {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::MAX, f64::min);
    println!("{name:<44} mean {:>9.3} ms   min {:>9.3} ms", mean * 1e3, min * 1e3);
    results.push((name.to_string(), mean * 1e3, min * 1e3));
}

fn write_json(results: &Results) {
    let path = std::env::var("GS_BENCH_OUT").unwrap_or_else(|_| "BENCH_micro.json".to_string());
    let mut body = String::from("{\n");
    for (i, (name, mean, min)) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        body.push_str(&format!(
            "  \"{name}\": {{\"mean_ms\": {mean:.4}, \"min_ms\": {min:.4}}}{comma}\n"
        ));
    }
    body.push_str("}\n");
    match std::fs::write(&path, &body) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// The rgcn_nc_train spec from the manifest when present, else a
/// synthetic twin with the same block shape — the sampling and
/// pipeline benches never need artifacts.
fn nc_spec(rt: Option<&Runtime>) -> ArtifactSpec {
    if let Some(rt) = rt {
        if let Ok(s) = rt.manifest.get("rgcn_nc_train") {
            return s.clone();
        }
    }
    ArtifactSpec::synthetic_block(&[2304, 384, 64], &[1920, 320], 5, r#","batch":64"#)
}

/// Stand-in for a device step when no PJRT backend is available:
/// a fixed slab of FLOPs on the consumer thread (identical for the
/// serial and prefetch arms, so the comparison stays fair).
fn simulated_step() {
    let mut acc = 0.0f64;
    for i in 0..400_000u64 {
        acc = acc.mul_add(1.000000119, (i & 1023) as f64 * 1e-9);
    }
    std::hint::black_box(acc);
}

fn main() {
    println!("=== micro benches (perf pass) ===");
    let mut results: Results = vec![];
    let rt = runtime_if_available();
    if rt.is_none() {
        println!("(AOT artifacts / PJRT unavailable — step benches skipped, pipeline uses a simulated step)");
    }
    // Workload parameters live in scripts/bench_micro.json (versioned)
    // rather than shell flags; GS_BENCH_CONF overrides the path.
    let conf = common::BenchConf::load(&["mag_papers", "parts", "pipeline_batches", "sf_edges"]);
    let mut ds =
        common::mag_dataset(common::scale(conf.usize("mag_papers", 4000)), conf.usize("parts", 2));
    ds.ensure_text_features(64);
    let spec = nc_spec(rt.as_ref());
    let shape = BlockShape::from_spec(&spec).unwrap();
    let sampler = NeighborSampler::new(&ds.graph);
    let train_ids = ds.node_labels().ids_in(Split::Train);
    let mut rng = Rng::seed_from(1);
    let seeds: Vec<(u32, u32)> = train_ids.iter().take(64).map(|&i| (0u32, i)).collect();

    // The hot path the trainers use: reusable scratch + block.
    let mut scratch = SamplerScratch::new();
    let mut block = Block::empty(&shape);
    bench(&mut results, "neighbor_sample (64 seeds, 2 hops)", 50, || {
        sampler.sample_block_with(
            &seeds,
            &shape,
            &mut rng,
            &EdgeExclusion::new(),
            &mut scratch,
            &mut block,
        );
        std::hint::black_box(block.nodes.len());
    });

    // The pre-refactor convenience path (fresh allocations per call),
    // kept for the scratch-reuse delta.
    bench(&mut results, "neighbor_sample (fresh alloc per call)", 50, || {
        let b = sampler.sample_block(&seeds, &shape, &mut rng, &EdgeExclusion::new());
        std::hint::black_box(b.nodes.len());
    });

    let block_fixed = sampler.sample_block(&seeds, &shape, &mut rng, &EdgeExclusion::new());
    bench(&mut results, "assemble_block_inputs", 50, || {
        let (b, _) = assemble_block_inputs(&ds, &block_fixed, &spec, 0).unwrap();
        std::hint::black_box(b.len());
    });

    // Buffer-recycling assembly (the serving ring): same values as the
    // row above, zero steady-state allocation.
    let mut asm = AssembleScratch::default();
    let mut ring: [(Vec<graphstorm::runtime::Tensor>, LembTouch); 2] =
        [(vec![], vec![]), (vec![], vec![])];
    let mut flip = 0usize;
    bench(&mut results, "assemble_block_inputs_into (ring)", 50, || {
        flip ^= 1;
        let (out, touch) = &mut ring[flip];
        assemble_block_inputs_into(&ds, &block_fixed, &spec, 0, false, &mut asm, out, touch)
            .unwrap();
        std::hint::black_box(out.len());
    });

    let loader = NodeDataLoader::new(&spec).unwrap();
    let chunk: Vec<u32> = train_ids.iter().take(64).copied().collect();
    let mut factory = BatchFactory::new(&ds, &shape);
    bench(&mut results, "full NC batch build", 30, || {
        let (b, _) = build_nc_batch(&mut factory, &loader, &chunk, &mut rng, 0, false).unwrap();
        std::hint::black_box(b.len());
    });

    // ---- pipeline throughput: serial vs prefetch -------------------------
    // One "epoch" of batch building + consuming; the consumer runs the
    // real PJRT step when available, a fixed FLOP slab otherwise.
    {
        let n_batches = conf.usize("pipeline_batches", 24).min(train_ids.len() / 64);
        let chunks: Vec<&[u32]> = train_ids.chunks(64).take(n_batches).collect();
        let mut st = rt
            .as_ref()
            .and_then(|rt| graphstorm::runtime::TrainState::new(rt, "rgcn_nc_train").ok());
        for workers in [1usize, 2, 4] {
            let label = if workers == 1 {
                "pipeline epoch (serial)".to_string()
            } else {
                format!("pipeline epoch (prefetch, {workers} workers)")
            };
            let cfg = PrefetchConfig { n_workers: workers, depth: 2 };
            bench(&mut results, &label, 5, || {
                run_pipeline(
                    &chunks,
                    &cfg,
                    || BatchFactory::new(&ds, &shape),
                    |f, bi, chunk| {
                        let mut rng = Rng::seed_from(batch_seed(7, 0, bi as u64));
                        build_nc_batch(f, &loader, chunk, &mut rng, 0, true)
                    },
                    |_bi, (mut batch, touch)| {
                        fill_lemb(&ds, &mut batch, &touch, 0)?;
                        match (&mut st, rt.as_ref()) {
                            (Some(st), Some(rt)) => {
                                let o = st.step(rt, &[3e-3], &batch)?;
                                std::hint::black_box(o.loss);
                            }
                            _ => simulated_step(),
                        }
                        std::hint::black_box(batch.len());
                        Ok(())
                    },
                )
                .unwrap();
            });
        }
    }

    // ---- AOT step latency (sample once, step many) -----------------------
    if let Some(rt) = rt.as_ref() {
        let mut st = graphstorm::runtime::TrainState::new(rt, "rgcn_nc_train").unwrap();
        let (batch, _, _) = loader.batch(&ds, &chunk, &mut rng, 0).unwrap();
        bench(&mut results, "rgcn_nc_train step (pallas)", 20, || {
            let o = st.step(rt, &[3e-3], &batch).unwrap();
            std::hint::black_box(o.loss);
        });
        if let Ok(spec_fast) = rt.manifest.get("rgcn_nc_train_fast").map(Clone::clone) {
            let loader_fast = NodeDataLoader::new(&spec_fast).unwrap();
            let mut st2 = graphstorm::runtime::TrainState::new(rt, "rgcn_nc_train_fast").unwrap();
            let (batch2, _, _) = loader_fast.batch(&ds, &chunk, &mut rng, 0).unwrap();
            bench(&mut results, "rgcn_nc_train step (xla scatter)", 20, || {
                let o = st2.step(rt, &[3e-3], &batch2).unwrap();
                std::hint::black_box(o.loss);
            });
        }

        // End-to-end epoch throughput through the trainer.
        bench(&mut results, "NC epoch (train split)", 3, || {
            let trainer = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_logits");
            let mut ds2 = common::mag_dataset(1000, 1);
            ds2.ensure_text_features(64);
            let (r, _) = trainer.fit(rt, &mut ds2, &common::opts(1, 1)).unwrap();
            std::hint::black_box(r.steps);
        });
    }

    // ---- partitioners ----------------------------------------------------
    let sf_edges = conf.usize("sf_edges", 200_000);
    let (dsf, _, _) = common::sf_dataset(sf_edges, 1);
    let sf_label = format!("{}K edges", sf_edges / 1000);
    bench(&mut results, &format!("random_partition ({sf_label})"), 10, || {
        let b = random_partition(&dsf.graph, 8, 3);
        std::hint::black_box(b.n_parts);
    });
    bench(&mut results, &format!("metis_like_partition ({sf_label})"), 3, || {
        let b = metis_like_partition(&dsf.graph, 8, 3);
        std::hint::black_box(b.n_parts);
    });

    // ---- feature gather --------------------------------------------------
    let ids: Vec<u32> = (0..2304u32).map(|i| i % ds.graph.num_nodes[3] as u32).collect();
    bench(&mut results, "DistTensor gather 2304 x 64", 100, || {
        let v = ds.engine.features[3].gather(0, &ids);
        std::hint::black_box(v.len());
    });
    let mut buf = vec![0.0f32; ids.len() * ds.engine.features[3].dim];
    bench(&mut results, "DistTensor gather_into 2304 x 64", 100, || {
        ds.engine.features[3].gather_into(0, &ids, &mut buf);
        std::hint::black_box(buf.len());
    });

    write_json(&results);
}
