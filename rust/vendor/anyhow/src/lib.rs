//! Minimal in-tree shim of the `anyhow` API surface this workspace
//! uses (offline build — DESIGN.md §1): `Error`, `Result`, `anyhow!`,
//! `bail!`, `ensure!`, and the `Context` extension trait for both
//! `Result` and `Option`.  Context is stored as a prefix chain in the
//! rendered message, matching anyhow's `{:#}` style closely enough for
//! logs and test assertions.

use std::fmt;

/// A type-erased error: the rendered message plus an optional source
/// chain already folded into the message (we never downcast).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line, anyhow-style (`context: cause`).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`,
// exactly like the real anyhow — that is what makes the blanket
// conversion below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Fold the source chain into one line.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg = format!("{msg}: {s}");
            src = s.source();
        }
        Error { msg }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!("...")` — construct an ad-hoc error from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("...")` — early-return an ad-hoc error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "...")` — bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

/// The `.context(..)` / `.with_context(|| ..)` extension trait.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_and_context_render() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: boom 42");
    }

    #[test]
    fn std_errors_convert() {
        let r: Result<String> = std::fs::read_to_string("/definitely/not/here")
            .with_context(|| "read config".to_string());
        let msg = r.unwrap_err().to_string();
        assert!(msg.starts_with("read config: "), "{msg}");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(30).is_err());
    }
}
