//! Minimal in-tree shim of the `anyhow` API surface this workspace
//! uses (offline build — DESIGN.md §1): `Error`, `Result`, `anyhow!`,
//! `bail!`, `ensure!`, `Error::new` + `downcast_ref` (the serving
//! stack classifies typed `ServeError`s this way), and the `Context`
//! extension trait for both `Result` and `Option`.  Context is stored
//! as a prefix chain in the rendered message, matching anyhow's `{:#}`
//! style closely enough for logs and test assertions.

use std::any::Any;
use std::fmt;

/// A type-erased error: the rendered message (source chain already
/// folded in) plus the original typed error when one existed, kept
/// for `downcast_ref` — ad-hoc `anyhow!` errors carry no payload.
pub struct Error {
    msg: String,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), payload: None }
    }

    /// Construct from a typed error, rendering its source chain into
    /// the message and retaining the value for [`downcast_ref`].
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg = format!("{msg}: {s}");
            src = s.source();
        }
        Error { msg, payload: Some(Box::new(e)) }
    }

    /// The typed error this was built from, if it was (or wraps) a
    /// `T`.  Context prefixes don't disturb the payload.
    pub fn downcast_ref<T: 'static>(&self) -> Option<&T> {
        self.payload.as_ref()?.downcast_ref::<T>()
    }

    /// Prepend a context line, anyhow-style (`context: cause`).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg), payload: self.payload }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`,
// exactly like the real anyhow — that is what makes the blanket
// conversion below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!("...")` — construct an ad-hoc error from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("...")` — early-return an ad-hoc error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "...")` — bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

/// The `.context(..)` / `.with_context(|| ..)` extension trait.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_and_context_render() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: boom 42");
    }

    #[test]
    fn std_errors_convert() {
        let r: Result<String> = std::fs::read_to_string("/definitely/not/here")
            .with_context(|| "read config".to_string());
        let msg = r.unwrap_err().to_string();
        assert!(msg.starts_with("read config: "), "{msg}");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn downcast_ref_preserves_typed_errors() {
        #[derive(Debug, PartialEq)]
        struct MyErr(u32);
        impl fmt::Display for MyErr {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "my error {}", self.0)
            }
        }
        impl std::error::Error for MyErr {}

        let e = Error::new(MyErr(7)).context("outer");
        assert_eq!(e.to_string(), "outer: my error 7");
        assert_eq!(e.downcast_ref::<MyErr>(), Some(&MyErr(7)));
        assert_eq!(e.downcast_ref::<std::io::Error>().map(|_| ()), None);
        assert!(anyhow!("ad hoc").downcast_ref::<MyErr>().is_none());
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(30).is_err());
    }
}
