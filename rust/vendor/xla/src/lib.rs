//! In-tree facade of the `xla` (xla_extension 0.5.1) API surface the
//! runtime uses.  Literal plumbing (create / to_vec / tuples / host
//! buffers) is fully functional, so everything up to and including
//! argument marshalling works offline; `PjRtLoadedExecutable::execute`
//! is the one seam that needs the real PJRT plugin and returns a clear
//! error here.  Swap this path dependency in `rust/Cargo.toml` for the
//! real bindings to run the AOT artifacts.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
}

impl ElementType {
    fn byte_size(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::Pred => 1,
        }
    }
}

/// Marker trait tying native types to XLA element types.
pub trait ArrayElement: Sized + Copy {
    const TY: ElementType;
    fn from_le_bytes(b: &[u8]) -> Self;
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le_bytes(b: &[u8]) -> f32 {
        f32::from_le_bytes(b.try_into().unwrap())
    }
}

impl ArrayElement for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le_bytes(b: &[u8]) -> i32 {
        i32::from_le_bytes(b.try_into().unwrap())
    }
}

/// A host literal: element type + dims + little-endian payload, or a
/// tuple of literals (the AOT train step returns a tuple root).
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        if n * ty.byte_size() != data.len() {
            return Err(Error::new(format!(
                "literal payload {} bytes, shape {dims:?} wants {}",
                data.len(),
                n * ty.byte_size()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec(), tuple: None })
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { ty: ElementType::Pred, dims: vec![], bytes: vec![], tuple: Some(parts) }
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error::new("to_vec on a tuple literal"));
        }
        if self.ty != T::TY {
            return Err(Error::new(format!("to_vec type mismatch ({:?})", self.ty)));
        }
        let sz = self.ty.byte_size();
        Ok(self.bytes.chunks_exact(sz).map(T::from_le_bytes).collect())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.tuple {
            Some(parts) => Ok(parts),
            None => Ok(vec![self]),
        }
    }
}

/// Parsed-enough HLO module: we retain the text for a real backend.
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::new(format!("read {}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { text })
    }
}

pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (no PJRT plugin in this build)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { client: self.clone() })
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { lit: lit.clone() })
    }
}

pub struct PjRtLoadedExecutable {
    client: PjRtClient,
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(
            "PJRT execution is unavailable in the offline stub; link the real \
             xla_extension bindings (see rust/vendor/xla) to run AOT artifacts",
        ))
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }
}

pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let data: Vec<u8> = [1.0f32, 2.0, 3.0].iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &data).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn shape_payload_mismatch_rejected() {
        let e = Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &[0u8; 4]);
        assert!(e.is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let a = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0u8; 4]).unwrap();
        let t = Literal::tuple(vec![a.clone(), a]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
    }

    #[test]
    fn execute_reports_missing_backend() {
        let c = PjRtClient::cpu().unwrap();
        let exe = c.compile(&XlaComputation::from_proto(&HloModuleProto { text: String::new() })).unwrap();
        let args: Vec<&Literal> = vec![];
        assert!(exe.execute(&args).is_err());
    }
}
